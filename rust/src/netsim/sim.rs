//! Round-synchronous network simulator core.
//!
//! The collectives under study (ring, recursive, tree, hierarchical) are
//! globally synchronous: every rank executes the same sequence of
//! *rounds*, and a round cannot start before the previous one finished.
//! Simulation therefore reduces to costing each round — the completion
//! time of its slowest resource — and summing. Per-round resource loads
//! are produced by [`crate::netsim::libmodel`]: for the PCCL models they
//! are read directly off the lowered, statically-verified plan
//! ([`crate::collectives::plan::phase_shapes`]), for the third-party
//! library models off the closed-form step math in
//! [`crate::collectives::schedule`] — which is what makes the simulated
//! pattern the shipped pattern.
//!
//! Round cost = `alpha` (startup/protocol latency)
//!            + max(busiest-NIC bytes / NIC bw, busiest intra-link bytes / link bw)
//!            + local reduce bytes / reduce bw
//!            + overflow-copy bytes / copy bw.

use crate::topology::{Machine, MachineParams};
use crate::util::rng::Rng;

/// Cost description of one communication round (possibly repeated, e.g.
/// the `p-1` identical steps of a ring).
#[derive(Debug, Clone, Default)]
pub struct RoundCost {
    /// Human label for traces ("inter-ring", "intra-ag", "shuffle", ...).
    pub label: &'static str,
    /// Startup latency per round (s).
    pub alpha: f64,
    /// Bytes through the busiest NIC this round.
    pub nic_bytes: f64,
    /// Bytes through the busiest intra-node link this round.
    pub intra_bytes: f64,
    /// Local combine volume per GPU this round (bytes).
    pub reduce_bytes: f64,
    /// Bandwidth for the combine (GPU or CPU — Observation 1).
    pub reduce_bw: f64,
    /// Software-copy volume per GPU (Cassini overflow path, §VI-B).
    pub copy_bytes: f64,
    /// Bandwidth of the overflow copy path.
    pub copy_bw: f64,
    /// Effective NIC-rail/lane occupancy of this round (`0` or `1` =
    /// single-lane). A `k`-lane striped round folds its stripes on `k`
    /// parallel lane workers, so the reduce and copy terms divide by the
    /// occupancy. The wire terms do NOT: with every GPU striping, node
    /// egress is unchanged and the busiest NIC carries the same bytes —
    /// the per-lane alpha penalty is charged by the model via `alpha`.
    pub rails: f64,
    /// Number of identical repetitions of this round.
    pub repeat: usize,
}

impl RoundCost {
    /// Seconds for one repetition given machine bandwidths.
    pub fn time_once(&self, p: &MachineParams) -> f64 {
        let rails = if self.rails > 1.0 { self.rails } else { 1.0 };
        let wire = (self.nic_bytes / p.nic_bw).max(self.intra_bytes / p.intra_bw);
        let reduce = if self.reduce_bytes > 0.0 {
            self.reduce_bytes / self.reduce_bw / rails
        } else {
            0.0
        };
        let copy = if self.copy_bytes > 0.0 {
            self.copy_bytes / self.copy_bw / rails
        } else {
            0.0
        };
        self.alpha + wire + reduce + copy
    }

    /// Seconds for all repetitions.
    pub fn time(&self, p: &MachineParams) -> f64 {
        self.time_once(p) * self.repeat.max(1) as f64
    }
}

/// A named sequence of rounds (one collective phase).
#[derive(Debug, Clone, Default)]
pub struct Phase {
    pub label: &'static str,
    pub rounds: Vec<RoundCost>,
}

impl Phase {
    pub fn time(&self, p: &MachineParams) -> f64 {
        self.rounds.iter().map(|r| r.time(p)).sum()
    }
}

/// The simulator: machine params + jitter RNG.
pub struct NetSim {
    machine: Machine,
    params: MachineParams,
    rng: Rng,
}

impl NetSim {
    pub fn new(machine: Machine, seed: u64) -> Self {
        Self {
            machine,
            params: machine.params(),
            rng: Rng::seed_from_u64(seed),
        }
    }

    pub fn machine(&self) -> Machine {
        self.machine
    }

    pub fn params(&self) -> &MachineParams {
        &self.params
    }

    /// Deterministic total time of a schedule (no jitter).
    pub fn time_deterministic(&self, phases: &[Phase]) -> f64 {
        phases.iter().map(|ph| ph.time(&self.params)).sum()
    }

    /// One simulated trial: deterministic time × lognormal jitter (the
    /// paper averages ten trials; RCCL all-reduce is notably variable).
    pub fn trial(&mut self, phases: &[Phase], extra_sigma: f64) -> f64 {
        let t = self.time_deterministic(phases);
        let sigma = self.params.jitter_sigma + extra_sigma;
        if sigma <= 0.0 {
            return t;
        }
        let z = self.rng.normal();
        t * (sigma * z).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(alpha: f64, nic: f64, intra: f64, repeat: usize) -> RoundCost {
        RoundCost {
            label: "t",
            alpha,
            nic_bytes: nic,
            intra_bytes: intra,
            repeat,
            ..Default::default()
        }
    }

    #[test]
    fn round_cost_is_max_of_resources() {
        let p = Machine::Generic.params();
        // 25 GB/s NIC, 50 GB/s intra (generic preset).
        let r = round(0.0, 25.0e9, 25.0e9, 1);
        // NIC takes 1 s, intra takes 0.5 s → max = 1 s.
        assert!((r.time(&p) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn repeats_scale_linearly() {
        let p = Machine::Generic.params();
        let r1 = round(1e-6, 1e6, 0.0, 1);
        let r10 = round(1e-6, 1e6, 0.0, 10);
        assert!((r10.time(&p) - 10.0 * r1.time(&p)).abs() < 1e-12);
    }

    #[test]
    fn reduce_and_copy_terms_add() {
        let p = Machine::Generic.params();
        let mut r = round(0.0, 0.0, 0.0, 1);
        r.reduce_bytes = p.gpu_reduce_bw; // 1 s of reduce
        r.reduce_bw = p.gpu_reduce_bw;
        r.copy_bytes = p.overflow_copy_bw; // 1 s of copy
        r.copy_bw = p.overflow_copy_bw;
        assert!((r.time(&p) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn rails_divide_reduce_and_copy_but_not_wire() {
        let p = Machine::Generic.params();
        let mut r = round(0.0, 25.0e9, 0.0, 1); // 1 s on the NIC
        r.reduce_bytes = p.gpu_reduce_bw; // 1 s of reduce single-lane
        r.reduce_bw = p.gpu_reduce_bw;
        assert!((r.time(&p) - 2.0).abs() < 1e-9);
        r.rails = 4.0;
        // Reduce drops to 0.25 s, wire stays at 1 s.
        assert!((r.time(&p) - 1.25).abs() < 1e-9);
    }

    #[test]
    fn generic_machine_has_no_jitter() {
        let mut sim = NetSim::new(Machine::Generic, 1);
        let ph = Phase {
            label: "x",
            rounds: vec![round(1e-3, 0.0, 0.0, 5)],
        };
        let t1 = sim.trial(&[ph.clone()], 0.0);
        let t2 = sim.trial(&[ph], 0.0);
        assert_eq!(t1, t2);
        assert!((t1 - 5e-3).abs() < 1e-12);
    }

    #[test]
    fn jitter_is_reproducible_by_seed() {
        let ph = vec![Phase {
            label: "x",
            rounds: vec![round(1e-3, 1e7, 0.0, 3)],
        }];
        let mut a = NetSim::new(Machine::Frontier, 42);
        let mut b = NetSim::new(Machine::Frontier, 42);
        assert_eq!(a.trial(&ph, 0.0), b.trial(&ph, 0.0));
        let mut c = NetSim::new(Machine::Frontier, 43);
        assert_ne!(a.trial(&ph, 0.0), c.trial(&ph, 0.0));
    }
}
