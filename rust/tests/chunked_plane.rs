//! The zero-copy chunked data plane, end to end:
//!
//! * **No intermediate materialization** — ring and hierarchical
//!   all-gather must deliver every block still backed by the *origin
//!   rank's input storage* (all ranks share one address space, so storage
//!   identity across threads is a direct proof that no hop copied).
//! * **Oracle equivalence on awkward shapes** — every collective over
//!   non-power-of-two rank counts (3, 6, 12) and uneven chunk splits.
//! * **Persistent world** — a ≥ 8-rank measured sweep over pinned rank
//!   threads reports byte-for-byte the same schedule volume as the
//!   spawn-per-trial mode, and the flat-ring cells match the closed-form
//!   schedule.

use pccl::backends::{
    all_gather, all_reduce, broadcast, gather, reduce_scatter, scatter, Backend, CollKind,
    CollectiveOptions,
};
use pccl::collectives::{
    hier_all_gather_chunks, oracle, pipelined_hier_all_gather, rec_all_gather,
    ring_all_gather_chunks, InterAlgo, Pccl,
};
use pccl::comm::{Chunk, CommWorld};
use pccl::runtime::{flat_ring_expected_bytes, Launcher, LauncherConfig};
use pccl::topology::Topology;

fn rank_input(r: usize, len: usize) -> Vec<f32> {
    (0..len).map(|i| (r * 1000 + i) as f32).collect()
}

#[test]
fn ring_all_gather_never_materializes_a_block() {
    let p = 6;
    let m = 8;
    let world = CommWorld::<f32>::new(p);
    let outs = world.run(move |c| {
        let input = Chunk::from_vec(rank_input(c.rank(), m));
        let own_id = input.storage_id();
        let blocks = ring_all_gather_chunks(c, input).unwrap();
        let ids: Vec<usize> = blocks.iter().map(Chunk::storage_id).collect();
        let data: Vec<Vec<f32>> = blocks.iter().map(|b| b.to_vec()).collect();
        (own_id, ids, data)
    });
    let origin_ids: Vec<usize> = outs.iter().map(|(id, _, _)| *id).collect();
    for (r, (_, ids, data)) in outs.iter().enumerate() {
        for q in 0..p {
            assert_eq!(
                ids[q], origin_ids[q],
                "rank {r} re-materialized block {q} (it must be a view of \
                 rank {q}'s input storage)"
            );
            assert_eq!(data[q], rank_input(q, m), "rank {r} block {q} content");
        }
    }
}

#[test]
fn hier_all_gather_never_materializes_a_block() {
    // 2 nodes × 4 GPUs = 8 ranks: blocks traverse an inter-node phase,
    // an intra-node forwarding ring, and the (pointer-only) unshuffle.
    let topo = Topology::new(2, 4, 1).unwrap();
    let p = topo.world_size();
    let m = 5;
    for algo in [InterAlgo::Ring, InterAlgo::Rec] {
        let world = CommWorld::<f32>::with_topology(topo);
        let outs = world.run(move |c| {
            let input = Chunk::from_vec(rank_input(c.rank(), m));
            let own_id = input.storage_id();
            let blocks = hier_all_gather_chunks(c, input, algo).unwrap();
            let ids: Vec<usize> = blocks.iter().map(Chunk::storage_id).collect();
            let data = Chunk::concat(&blocks);
            (own_id, ids, data)
        });
        let origin_ids: Vec<usize> = outs.iter().map(|(id, _, _)| *id).collect();
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
        let expect = oracle::all_gather(&ins);
        for (r, (_, ids, data)) in outs.iter().enumerate() {
            assert_eq!(data, &expect, "algo={algo:?} rank {r} output");
            for q in 0..p {
                assert_eq!(
                    ids[q], origin_ids[q],
                    "algo={algo:?}: rank {r} re-materialized block {q}"
                );
            }
        }
    }
}

#[test]
fn facade_chunked_all_gather_routes_and_forwards() {
    let topo = Topology::new(2, 3, 1).unwrap();
    let p = topo.world_size();
    let world = CommWorld::<f32>::with_topology(topo);
    let outs = world.run(move |c| {
        let facade = Pccl::<f32>::with_backend(Backend::PcclRing);
        let input = Chunk::from_vec(rank_input(c.rank(), 4));
        let own_id = input.storage_id();
        let blocks = facade.all_gather_chunks(c, input).unwrap();
        (own_id, blocks.iter().map(Chunk::storage_id).collect::<Vec<_>>(), Chunk::concat(&blocks))
    });
    let origin_ids: Vec<usize> = outs.iter().map(|(id, _, _)| *id).collect();
    let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, 4)).collect();
    let expect = oracle::all_gather(&ins);
    for (r, (_, ids, data)) in outs.iter().enumerate() {
        assert_eq!(data, &expect, "rank {r}");
        for q in 0..p {
            assert_eq!(ids[q], origin_ids[q], "facade rank {r} block {q}");
        }
    }
}

/// Every backend × every collective ≡ oracle on the non-power-of-two rank
/// counts the chunked refactor must not regress: 3, 6, 12.
#[test]
fn all_collectives_match_oracle_on_non_pow2_ranks() {
    let topos = [
        Topology::flat(3),
        Topology::new(3, 2, 1).unwrap(), // 6 ranks, non-pow2 nodes
        Topology::new(3, 4, 1).unwrap(), // 12 ranks
    ];
    for topo in topos {
        let p = topo.world_size();
        let m = 7; // prime block length → uneven against every split
        for backend in Backend::CONCRETE {
            let world = CommWorld::<f32>::with_topology(topo);
            let outs = world.run(move |c| {
                let opts = CollectiveOptions::default().backend(backend);
                let r = c.rank();
                let ag = all_gather(c, &rank_input(r, m), &opts).unwrap();
                let rs = reduce_scatter(c, &rank_input(r, p * 3), &opts).unwrap();
                let ar = all_reduce(c, &rank_input(r, m), &opts).unwrap();
                (ag, rs, ar)
            });
            let ag_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
            let rs_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * 3)).collect();
            for (r, (ag, rs, ar)) in outs.iter().enumerate() {
                assert_eq!(ag, &oracle::all_gather(&ag_ins), "{backend:?} ag p={p} r={r}");
                assert_eq!(
                    rs,
                    &oracle::reduce_scatter(&rs_ins, r),
                    "{backend:?} rs p={p} r={r}"
                );
                assert_eq!(ar, &oracle::all_reduce(&ag_ins), "{backend:?} ar p={p} r={r}");
            }
        }
    }
}

#[test]
fn pipelined_all_gather_uneven_chunk_splits() {
    // Chunk sizes deliberately misaligned with the rank count (cb = 5 on
    // p = 6) and with each other (split counts 2 and 5 over m = 10).
    let topo = Topology::new(3, 2, 1).unwrap();
    let p = topo.world_size();
    let m = 10;
    for chunks in [2usize, 5] {
        let world = CommWorld::<f32>::with_topology(topo);
        let outs = world.run(move |c| {
            pipelined_hier_all_gather(c, &rank_input(c.rank(), m), InterAlgo::Rec, chunks)
                .unwrap()
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
        let expect = oracle::all_gather(&ins);
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o, &expect, "chunks={chunks} r={r}");
        }
    }
}

#[test]
fn recursive_still_requires_pow2_and_hier_falls_back() {
    // Recursive on 3/6/12 must reject; the hierarchical Rec route must
    // silently take the ring fallback instead (covered above) — assert
    // the rejection is still a typed error, not a wrong answer.
    for p in [3usize, 6, 12] {
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(|c| rec_all_gather(c, &[1.0, 2.0]).is_err());
        assert!(outs.iter().all(|&e| e), "p={p}");
    }
}

#[test]
fn rooted_collectives_on_non_pow2_ranks() {
    for p in [3usize, 6, 12] {
        let root = p - 1;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let params = broadcast(c, &rank_input(root, 5), root).unwrap();
            let gathered = gather(c, &params[..2], root).unwrap();
            let shard = if c.rank() == root {
                scatter(c, &gathered, root).unwrap()
            } else {
                scatter(c, &[], root).unwrap()
            };
            (params, shard)
        });
        let expect_b = rank_input(root, 5);
        for (r, (params, shard)) in outs.iter().enumerate() {
            assert_eq!(params, &expect_b, "p={p} r={r} broadcast");
            assert_eq!(shard.as_slice(), &expect_b[..2], "p={p} r={r} scatter round-trip");
        }
    }
}

#[test]
fn persistent_world_sweep_matches_spawn_mode_bytes() {
    // ≥ 8 ranks, hierarchical topology, both launcher modes: identical
    // schedule volume per cell proves the chunked plane changed *copies*,
    // never *communication*.
    let base = LauncherConfig {
        topologies: vec![Topology::new(2, 4, 1).unwrap()],
        elem_counts: vec![256, 1024],
        trials: 2,
        inner_iters: 2,
        warmup_iters: 1,
        persistent: false,
    };
    let spawn = Launcher::new(base.clone()).sweep().unwrap();
    let persist = Launcher::new(base.with_persistent(true)).sweep().unwrap();
    assert_eq!(spawn.cells.len(), persist.cells.len());
    assert_eq!(spawn.cells.len(), 2 * 3 * 4); // sizes × collectives × backends
    for (a, b) in spawn.cells.iter().zip(&persist.cells) {
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.backend, b.backend);
        assert_eq!(a.ranks, 8);
        assert!(b.stats.mean() > 0.0, "{:?}/{:?}", b.kind, b.backend);
        assert_eq!(
            a.bytes_per_op, b.bytes_per_op,
            "schedule volume diverged for {:?}/{:?} at {} B",
            a.kind, a.backend, a.msg_bytes
        );
        assert!(a.bytes_per_op > 0);
    }
    // Flat-ring backends must also match the closed-form schedule volume.
    for c in &persist.cells {
        if !matches!(c.backend, Backend::Vendor | Backend::CrayMpich) {
            continue;
        }
        if let Some(expect) = flat_ring_expected_bytes(c.kind, c.msg_bytes / 4, c.ranks) {
            assert_eq!(
                c.bytes_per_op, expect,
                "analytic ring volume for {:?} at {} B",
                c.kind, c.msg_bytes
            );
        }
    }
    // And the measured sweep still trains a dispatcher end to end.
    let d = persist
        .train_dispatcher(pccl::topology::Machine::Generic, 7)
        .unwrap();
    assert!(Backend::CONCRETE.contains(&d.choose(CollKind::AllGather, 4096, 8)));
}
