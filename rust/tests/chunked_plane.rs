//! The zero-copy chunked data plane, end to end:
//!
//! * **No intermediate materialization** — ring and hierarchical
//!   all-gather must deliver every block still backed by the *origin
//!   rank's input storage* (all ranks share one address space, so storage
//!   identity across threads is a direct proof that no hop copied).
//! * **Reduce path zero-copy** — `*_reduce_scatter_chunks` must hand back
//!   the transport-delivered traveling partial as a unique full-range
//!   chunk (`into_vec` pointer-identical move, no copy), proving the
//!   ZeRO-3 shard update lands in transport storage with zero copies.
//! * **Oracle equivalence on awkward shapes** — every collective (and the
//!   chunk-native reduce entry points) over non-power-of-two rank counts
//!   (3, 6, 12), uneven chunk splits, and padded all-reduce sizes.
//! * **Op-sequence discipline** — `p == 1` reduce paths advance the op
//!   sequence exactly like `p > 1`, so wire tags never alias.
//! * **Persistent world** — a ≥ 8-rank measured sweep over pinned rank
//!   threads reports byte-for-byte the same schedule volume as the
//!   spawn-per-trial mode, and the flat-library cells match the
//!   closed-form schedule.

use std::collections::{HashMap, VecDeque};

use pccl::backends::{
    all_gather, all_reduce, all_reduce_chunks, broadcast, gather, reduce_scatter,
    reduce_scatter_chunks, scatter, Backend, CollKind, CollectiveOptions,
};
use pccl::collectives::{
    hier_all_gather_chunks, hier_all_reduce, hier_reduce_scatter_chunks, oracle,
    pipelined_hier_all_gather, pipelined_hier_all_reduce_chunks,
    pipelined_hier_reduce_scatter_chunks, rec_all_gather, rec_all_reduce,
    rec_reduce_scatter_chunks, ring_all_gather_chunks, ring_all_reduce, ring_reduce_scatter,
    ring_reduce_scatter_chunks, InterAlgo, Pccl,
};
use pccl::comm::{Chunk, Comm, CommWorld, Communicator};
use pccl::reduction::offload::native_combine;
use pccl::runtime::{expected_schedule_bytes, Launcher, LauncherConfig};
use pccl::topology::Topology;

fn rank_input(r: usize, len: usize) -> Vec<f32> {
    (0..len).map(|i| (r * 1000 + i) as f32).collect()
}

#[test]
fn ring_all_gather_never_materializes_a_block() {
    let p = 6;
    let m = 8;
    let world = CommWorld::<f32>::new(p);
    let outs = world.run(move |c| {
        let input = Chunk::from_vec(rank_input(c.rank(), m));
        let own_id = input.storage_id();
        let blocks = ring_all_gather_chunks(c, input).unwrap();
        let ids: Vec<usize> = blocks.iter().map(Chunk::storage_id).collect();
        let data: Vec<Vec<f32>> = blocks.iter().map(|b| b.to_vec()).collect();
        (own_id, ids, data)
    });
    let origin_ids: Vec<usize> = outs.iter().map(|(id, _, _)| *id).collect();
    for (r, (_, ids, data)) in outs.iter().enumerate() {
        for q in 0..p {
            assert_eq!(
                ids[q], origin_ids[q],
                "rank {r} re-materialized block {q} (it must be a view of \
                 rank {q}'s input storage)"
            );
            assert_eq!(data[q], rank_input(q, m), "rank {r} block {q} content");
        }
    }
}

#[test]
fn hier_all_gather_never_materializes_a_block() {
    // 2 nodes × 4 GPUs = 8 ranks: blocks traverse an inter-node phase,
    // an intra-node forwarding ring, and the (pointer-only) unshuffle.
    let topo = Topology::new(2, 4, 1).unwrap();
    let p = topo.world_size();
    let m = 5;
    for algo in [InterAlgo::Ring, InterAlgo::Rec] {
        let world = CommWorld::<f32>::with_topology(topo);
        let outs = world.run(move |c| {
            let input = Chunk::from_vec(rank_input(c.rank(), m));
            let own_id = input.storage_id();
            let blocks = hier_all_gather_chunks(c, input, algo).unwrap();
            let ids: Vec<usize> = blocks.iter().map(Chunk::storage_id).collect();
            let data = Chunk::concat(&blocks);
            (own_id, ids, data)
        });
        let origin_ids: Vec<usize> = outs.iter().map(|(id, _, _)| *id).collect();
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
        let expect = oracle::all_gather(&ins);
        for (r, (_, ids, data)) in outs.iter().enumerate() {
            assert_eq!(data, &expect, "algo={algo:?} rank {r} output");
            for q in 0..p {
                assert_eq!(
                    ids[q], origin_ids[q],
                    "algo={algo:?}: rank {r} re-materialized block {q}"
                );
            }
        }
    }
}

#[test]
fn facade_chunked_all_gather_routes_and_forwards() {
    let topo = Topology::new(2, 3, 1).unwrap();
    let p = topo.world_size();
    let world = CommWorld::<f32>::with_topology(topo);
    let outs = world.run(move |c| {
        let facade = Pccl::<f32>::with_backend(Backend::PcclRing);
        let input = Chunk::from_vec(rank_input(c.rank(), 4));
        let own_id = input.storage_id();
        let blocks = facade.all_gather_chunks(c, input).unwrap();
        (own_id, blocks.iter().map(Chunk::storage_id).collect::<Vec<_>>(), Chunk::concat(&blocks))
    });
    let origin_ids: Vec<usize> = outs.iter().map(|(id, _, _)| *id).collect();
    let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, 4)).collect();
    let expect = oracle::all_gather(&ins);
    for (r, (_, ids, data)) in outs.iter().enumerate() {
        assert_eq!(data, &expect, "rank {r}");
        for q in 0..p {
            assert_eq!(ids[q], origin_ids[q], "facade rank {r} block {q}");
        }
    }
}

/// Every backend × every collective ≡ oracle on the non-power-of-two rank
/// counts the chunked refactor must not regress: 3, 6, 12.
#[test]
fn all_collectives_match_oracle_on_non_pow2_ranks() {
    let topos = [
        Topology::flat(3),
        Topology::new(3, 2, 1).unwrap(), // 6 ranks, non-pow2 nodes
        Topology::new(3, 4, 1).unwrap(), // 12 ranks
    ];
    for topo in topos {
        let p = topo.world_size();
        let m = 7; // prime block length → uneven against every split
        for backend in Backend::CONCRETE {
            let world = CommWorld::<f32>::with_topology(topo);
            let outs = world.run(move |c| {
                let opts = CollectiveOptions::default().backend(backend);
                let r = c.rank();
                let ag = all_gather(c, &rank_input(r, m), &opts).unwrap();
                let rs = reduce_scatter(c, &rank_input(r, p * 3), &opts).unwrap();
                let ar = all_reduce(c, &rank_input(r, m), &opts).unwrap();
                (ag, rs, ar)
            });
            let ag_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
            let rs_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * 3)).collect();
            for (r, (ag, rs, ar)) in outs.iter().enumerate() {
                assert_eq!(ag, &oracle::all_gather(&ag_ins), "{backend:?} ag p={p} r={r}");
                assert_eq!(
                    rs,
                    &oracle::reduce_scatter(&rs_ins, r),
                    "{backend:?} rs p={p} r={r}"
                );
                assert_eq!(ar, &oracle::all_reduce(&ag_ins), "{backend:?} ar p={p} r={r}");
            }
        }
    }
}

#[test]
fn pipelined_all_gather_uneven_chunk_splits() {
    // Chunk sizes deliberately misaligned with the rank count (cb = 5 on
    // p = 6) and with each other (split counts 2 and 5 over m = 10).
    let topo = Topology::new(3, 2, 1).unwrap();
    let p = topo.world_size();
    let m = 10;
    for chunks in [2usize, 5] {
        let world = CommWorld::<f32>::with_topology(topo);
        let outs = world.run(move |c| {
            pipelined_hier_all_gather(c, &rank_input(c.rank(), m), InterAlgo::Rec, chunks)
                .unwrap()
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
        let expect = oracle::all_gather(&ins);
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o, &expect, "chunks={chunks} r={r}");
        }
    }
}

#[test]
fn recursive_still_requires_pow2_and_hier_falls_back() {
    // Recursive on 3/6/12 must reject; the hierarchical Rec route must
    // silently take the ring fallback instead (covered above) — assert
    // the rejection is still a typed error, not a wrong answer.
    for p in [3usize, 6, 12] {
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(|c| rec_all_gather(c, &[1.0, 2.0]).is_err());
        assert!(outs.iter().all(|&e| e), "p={p}");
    }
}

#[test]
fn rooted_collectives_on_non_pow2_ranks() {
    for p in [3usize, 6, 12] {
        let root = p - 1;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let params = broadcast(c, &rank_input(root, 5), root).unwrap();
            let gathered = gather(c, &params[..2], root).unwrap();
            let shard = if c.rank() == root {
                scatter(c, &gathered, root).unwrap()
            } else {
                scatter(c, &[], root).unwrap()
            };
            (params, shard)
        });
        let expect_b = rank_input(root, 5);
        for (r, (params, shard)) in outs.iter().enumerate() {
            assert_eq!(params, &expect_b, "p={p} r={r} broadcast");
            assert_eq!(shard.as_slice(), &expect_b[..2], "p={p} r={r} scatter round-trip");
        }
    }
}

#[test]
fn persistent_world_sweep_matches_spawn_mode_bytes() {
    // ≥ 8 ranks, hierarchical topology, both launcher modes: identical
    // schedule volume per cell proves the chunked plane changed *copies*,
    // never *communication*.
    let base = LauncherConfig {
        topologies: vec![Topology::new(2, 4, 1).unwrap()],
        elem_counts: vec![256, 1024],
        trials: 2,
        inner_iters: 2,
        warmup_iters: 1,
        persistent: false,
        lane_counts: vec![1],
    };
    let spawn = Launcher::new(base.clone()).sweep().unwrap();
    let persist = Launcher::new(base.with_persistent(true)).sweep().unwrap();
    assert_eq!(spawn.cells.len(), persist.cells.len());
    assert_eq!(spawn.cells.len(), 2 * 3 * 4); // sizes × collectives × backends
    for (a, b) in spawn.cells.iter().zip(&persist.cells) {
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.backend, b.backend);
        assert_eq!(a.ranks, 8);
        assert!(b.stats.mean() > 0.0, "{:?}/{:?}", b.kind, b.backend);
        assert_eq!(
            a.bytes_per_op, b.bytes_per_op,
            "schedule volume diverged for {:?}/{:?} at {} B",
            a.kind, a.backend, a.msg_bytes
        );
        assert!(a.bytes_per_op > 0);
    }
    // Flat-library cells must also match the closed-form schedule volume —
    // including the ring all-reduce composition on Cray-MPICH, so the
    // reduce path is guarded end to end, not just the gather path.
    let mut checked_all_reduce = false;
    for c in &persist.cells {
        if let Some(expect) = expected_schedule_bytes(c.kind, c.backend, c.msg_bytes / 4, c.ranks)
        {
            assert_eq!(
                c.bytes_per_op, expect,
                "analytic schedule volume for {:?}/{:?} at {} B",
                c.kind, c.backend, c.msg_bytes
            );
            checked_all_reduce |= c.kind == CollKind::AllReduce;
        }
    }
    assert!(checked_all_reduce, "all-reduce must be in the closed-form guard");
    // And the measured sweep still trains a dispatcher end to end.
    let d = persist
        .train_dispatcher(pccl::topology::Machine::Generic, 7)
        .unwrap();
    assert!(Backend::CONCRETE.contains(&d.choose(CollKind::AllGather, 4096, 8)));
}

/// Flat-ring reduce-scatter must deliver the traveling partial itself:
/// fresh exact storage (never a view of this rank's input), uniquely
/// owned and full-range, so `into_vec` is a pointer-identical move.
#[test]
fn ring_reduce_scatter_chunk_is_move_free_transport_storage() {
    let p = 6;
    let b = 4;
    let world = CommWorld::<f32>::new(p);
    let outs = world.run(move |c| {
        let input = Chunk::from_vec(rank_input(c.rank(), p * b));
        let input_id = input.storage_id();
        let shard = ring_reduce_scatter_chunks(c, input, &native_combine()).unwrap();
        // The input storage is alive for the whole collective on this
        // thread, so a distinct id proves the result is fresh storage.
        assert_ne!(shard.storage_id(), input_id, "result must not alias the input");
        assert_eq!(shard.storage_refs(), 1, "result must be uniquely owned");
        assert!(shard.is_full_view(), "result must be exact-size storage");
        let ptr = shard.as_slice().as_ptr() as usize;
        let v = shard.into_vec();
        assert_eq!(v.as_ptr() as usize, ptr, "into_vec must move, not copy");
        v
    });
    let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
    for (r, o) in outs.iter().enumerate() {
        assert_eq!(o, &oracle::reduce_scatter(&ins, r), "r={r}");
    }
}

/// The ZeRO-3 step shape through the facade, at 8 ranks over a 2×4
/// hierarchy, for every backend: shard chunk → all-gather views →
/// gradient chunk → `reduce_scatter_chunks` → in-place scale → update.
/// The delivered gradient shard must be consumed with zero copies on the
/// aligned path (the PR's acceptance proof).
#[test]
fn zero3_style_shard_reduce_scatter_is_zero_copy() {
    let topo = Topology::new(2, 4, 1).unwrap();
    let p = topo.world_size();
    let shard_len = 6;
    for backend in Backend::CONCRETE {
        let world = CommWorld::<f32>::with_topology(topo);
        let outs = world.run(move |c| {
            let facade = Pccl::<f32>::with_backend(backend);
            let shard = Chunk::from_vec(rank_input(c.rank(), shard_len));
            let blocks = facade.all_gather_chunks(c, shard.clone()).unwrap();
            assert_eq!(Chunk::concat(&blocks).len(), p * shard_len);
            let grad = Chunk::from_vec(rank_input(c.rank(), p * shard_len));
            let mut gshard = facade.reduce_scatter_chunks(c, grad).unwrap();
            let delivered = gshard.storage_id();
            assert_eq!(gshard.storage_refs(), 1, "{backend:?}: shared grad shard");
            assert!(gshard.is_full_view(), "{backend:?}: padded/view grad shard");
            // Gradient averaging mutates the delivered storage in place.
            for g in gshard.make_mut() {
                *g *= 0.5;
            }
            assert_eq!(
                gshard.storage_id(),
                delivered,
                "{backend:?}: in-place scale must not re-materialize"
            );
            // And handing it to the optimizer costs no copy either.
            let ptr = gshard.as_slice().as_ptr() as usize;
            let v = gshard.into_vec();
            assert_eq!(
                v.as_ptr() as usize,
                ptr,
                "{backend:?}: into_vec on the aligned path must be a move"
            );
            v.iter().map(|x| x * 2.0).collect::<Vec<f32>>()
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * shard_len)).collect();
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(
                o,
                &oracle::reduce_scatter(&ins, r),
                "{backend:?} r={r} content"
            );
        }
    }
}

/// Chunk-native reduce entry points ≡ oracle on non-power-of-two rank
/// counts (3, 6, 12) with a padded (p ∤ n) all-reduce length on every
/// backend, and the trimmed block list must concatenate to exactly `n`.
#[test]
fn chunk_reduce_paths_match_oracle_on_non_pow2_and_padded_sizes() {
    let topos = [
        Topology::flat(3),
        Topology::new(3, 2, 1).unwrap(), // 6 ranks, non-pow2 nodes
        Topology::new(3, 4, 1).unwrap(), // 12 ranks
    ];
    for topo in topos {
        let p = topo.world_size();
        let n_ar = 2 * p + 1; // never a multiple of p → padded path
        for backend in Backend::CONCRETE {
            let world = CommWorld::<f32>::with_topology(topo);
            let outs = world.run(move |c| {
                let opts = CollectiveOptions::default().backend(backend);
                let r = c.rank();
                let rs = reduce_scatter_chunks(c, Chunk::from_vec(rank_input(r, p * 3)), &opts)
                    .unwrap();
                let ar_blocks =
                    all_reduce_chunks(c, Chunk::from_vec(rank_input(r, n_ar)), &opts).unwrap();
                let ar = Chunk::concat(&ar_blocks);
                assert_eq!(ar.len(), n_ar, "{backend:?}: trim must drop the padding");
                (rs.to_vec(), ar)
            });
            let rs_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * 3)).collect();
            let ar_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, n_ar)).collect();
            let ar_expect = oracle::all_reduce(&ar_ins);
            for (r, (rs, ar)) in outs.iter().enumerate() {
                assert_eq!(
                    rs,
                    &oracle::reduce_scatter(&rs_ins, r),
                    "{backend:?} rs p={p} r={r}"
                );
                assert_eq!(ar, &ar_expect, "{backend:?} ar p={p} r={r}");
            }
        }
    }
}

/// Single-rank loopback communicator that counts op-sequence bumps (the
/// collectives under test move no bytes at `p == 1`, but any send/recv
/// they do issue round-trips through the step-keyed queues).
struct LoopbackComm {
    queues: HashMap<u32, VecDeque<Chunk<f32>>>,
    ops: u64,
}

impl Comm<f32> for LoopbackComm {
    fn rank(&self) -> usize {
        0
    }
    fn size(&self) -> usize {
        1
    }
    fn send_slice(&mut self, _peer: usize, step: u32, chunk: Chunk<f32>) -> pccl::Result<()> {
        self.queues.entry(step).or_default().push_back(chunk);
        Ok(())
    }
    fn recv_chunk(&mut self, _peer: usize, step: u32) -> pccl::Result<Chunk<f32>> {
        Ok(self
            .queues
            .get_mut(&step)
            .and_then(VecDeque::pop_front)
            .expect("loopback recv with no matching send"))
    }
    fn begin_op(&mut self) {
        self.ops += 1;
    }
}

fn op_bumps(f: impl FnOnce(&mut LoopbackComm)) -> u64 {
    let mut c = LoopbackComm { queues: HashMap::new(), ops: 0 };
    f(&mut c);
    c.ops
}

/// Regression (tag-sequence consistency): every collective must advance
/// the op sequence the same number of times at `p == 1` as at `p > 1` —
/// one bump per component collective, two for the RS ∘ AG all-reduce
/// composition. The old early returns bumped zero times.
#[test]
fn p1_reduce_paths_bump_op_sequence_like_p_gt_1() {
    let two = [1.0f32, 2.0];
    assert_eq!(
        op_bumps(|c| {
            ring_all_reduce(c, &two, &native_combine()).unwrap();
        }),
        2,
        "ring all-reduce = RS + AG"
    );
    assert_eq!(
        op_bumps(|c| {
            rec_all_reduce(c, &two, &native_combine()).unwrap();
        }),
        2,
        "recursive all-reduce = RS + AG"
    );
    assert_eq!(
        op_bumps(|c| {
            ring_reduce_scatter(c, &two, &native_combine()).unwrap();
        }),
        1,
        "reduce-scatter is one collective"
    );
    assert_eq!(
        op_bumps(|c| {
            rec_all_gather(c, &two).unwrap();
        }),
        1,
        "all-gather is one collective"
    );
}

/// Regression (wire-tag freshness): at `p == 1` a collective that fails to
/// bump the op sequence leaves the communicator composing the *same* tags
/// as before the call — an unreceived earlier message would then be
/// matched by a later receive (FIFO per tag). Probe exactly that on the
/// real transport.
#[test]
fn p1_all_reduce_advances_wire_tags() {
    fn probe<F: FnOnce(&mut Communicator<f32>)>(c: &mut Communicator<f32>, f: F) -> Vec<f32> {
        c.begin_op();
        // Stale message, deliberately never received.
        c.send_slice(0, 7, Chunk::from_vec(vec![111.0])).unwrap();
        f(c);
        // If `f` advanced the op sequence, this send/recv pair uses fresh
        // tags and the recv sees 222; if not, it matches the stale 111.
        c.send_slice(0, 7, Chunk::from_vec(vec![222.0])).unwrap();
        c.recv_chunk(0, 7).unwrap().to_vec()
    }
    let world = CommWorld::<f32>::new(1);
    let outs = world.run(|c| {
        let a = probe(c, |c| {
            ring_all_reduce(c, &[5.0], &native_combine()).unwrap();
        });
        let b = probe(c, |c| {
            rec_all_reduce(c, &[5.0], &native_combine()).unwrap();
        });
        let d = probe(c, |c| {
            hier_all_reduce(c, &[5.0], &native_combine(), InterAlgo::Rec).unwrap();
        });
        (a, b, d)
    });
    for (a, b, d) in outs {
        assert_eq!(a, vec![222.0], "ring all-reduce must advance wire tags");
        assert_eq!(b, vec![222.0], "rec all-reduce must advance wire tags");
        assert_eq!(d, vec![222.0], "hier all-reduce must advance wire tags");
    }
}

/// Storage-identity proof for every reduce backend, at 6 ranks (3×2 —
/// ring, hierarchical, pipelined) and 8 ranks (2×4 — all four, recursive
/// halving included): the delivered shard must be uniquely owned,
/// exact-size storage consumable by a pointer-identical `into_vec` move,
/// and the transport must deliver the whole collective with
/// `copied_bytes == 0` (the posted-receive acceptance bar).
#[test]
fn reduce_backends_deliver_exclusive_shard_storage() {
    for (topo, pow2) in [
        (Topology::new(3, 2, 1).unwrap(), false),
        (Topology::new(2, 4, 1).unwrap(), true),
    ] {
        let p = topo.world_size();
        let b = 6;
        let world = CommWorld::<f32>::with_topology(topo);
        let outs = world.run(move |c| {
            let comb = native_combine();
            let r = c.rank();
            let before = c.traffic().copied_bytes;
            let mut shards = vec![
                (
                    "ring",
                    ring_reduce_scatter_chunks(c, Chunk::from_vec(rank_input(r, p * b)), &comb)
                        .unwrap(),
                ),
                (
                    "hier",
                    hier_reduce_scatter_chunks(
                        c,
                        Chunk::from_vec(rank_input(r, p * b)),
                        &comb,
                        InterAlgo::Ring,
                    )
                    .unwrap(),
                ),
                (
                    "pipelined",
                    pipelined_hier_reduce_scatter_chunks(
                        c,
                        Chunk::from_vec(rank_input(r, p * b)),
                        &comb,
                        InterAlgo::Ring,
                        2,
                    )
                    .unwrap(),
                ),
            ];
            if pow2 {
                shards.push((
                    "rec",
                    rec_reduce_scatter_chunks(c, Chunk::from_vec(rank_input(r, p * b)), &comb)
                        .unwrap(),
                ));
            }
            let copied = c.traffic().copied_bytes - before;
            let out: Vec<(&str, Vec<f32>)> = shards
                .into_iter()
                .map(|(name, shard)| {
                    assert_eq!(
                        shard.storage_refs(),
                        1,
                        "{name} p={p}: shard must be uniquely owned"
                    );
                    assert!(shard.is_full_view(), "{name} p={p}: shard must be exact-size");
                    let ptr = shard.as_slice().as_ptr() as usize;
                    let v = shard.into_vec();
                    assert_eq!(v.as_ptr() as usize, ptr, "{name} p={p}: into_vec must move");
                    (name, v)
                })
                .collect();
            (copied, out)
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
        for (r, (copied, per_backend)) in outs.iter().enumerate() {
            assert_eq!(*copied, 0, "p={p} r={r}: reduce delivery must be copy-free");
            for (name, v) in per_backend {
                assert_eq!(v, &oracle::reduce_scatter(&ins, r), "{name} p={p} r={r}");
            }
        }
    }
}

/// Pipelined reduce path on chunk splits misaligned with the rank count
/// (cb = 5 and 2 on p = 6): the reassembled shard is still fresh unique
/// exact-size storage, the transport still copies nothing (the stage
/// staging gather is rank-local, pre-transport), and content matches the
/// oracle — including the padded pipelined all-reduce.
#[test]
fn pipelined_reduce_uneven_chunk_splits_deliver_fresh_storage() {
    let topo = Topology::new(3, 2, 1).unwrap();
    let p = topo.world_size();
    let b = 10;
    for chunks in [2usize, 5] {
        let world = CommWorld::<f32>::with_topology(topo);
        let outs = world.run(move |c| {
            let before = c.traffic().copied_bytes;
            let shard = pipelined_hier_reduce_scatter_chunks(
                c,
                Chunk::from_vec(rank_input(c.rank(), p * b)),
                &native_combine(),
                InterAlgo::Ring,
                chunks,
            )
            .unwrap();
            assert_eq!(
                c.traffic().copied_bytes - before,
                0,
                "chunks={chunks}: transport must not copy"
            );
            assert_eq!(shard.storage_refs(), 1, "chunks={chunks}: shared shard");
            assert!(shard.is_full_view(), "chunks={chunks}: padded/view shard");
            let ptr = shard.as_slice().as_ptr() as usize;
            let v = shard.into_vec();
            assert_eq!(v.as_ptr() as usize, ptr, "chunks={chunks}: into_vec must move");
            v
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o, &oracle::reduce_scatter(&ins, r), "chunks={chunks} r={r}");
        }
    }
    // Padded pipelined all-reduce: stage length 2 on 6 ranks pads inside
    // every stage; the block list must still trim back to exactly n.
    let n = 10;
    let world = CommWorld::<f32>::with_topology(topo);
    let outs = world.run(move |c| {
        let blocks = pipelined_hier_all_reduce_chunks(
            c,
            Chunk::from_vec(rank_input(c.rank(), n)),
            &native_combine(),
            InterAlgo::Ring,
            5,
        )
        .unwrap();
        let out = Chunk::concat(&blocks);
        assert_eq!(out.len(), n, "trim must drop the padding");
        out
    });
    let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, n)).collect();
    let expect = oracle::all_reduce(&ins);
    for (r, o) in outs.iter().enumerate() {
        assert_eq!(o, &expect, "padded pipelined all-reduce r={r}");
    }
}

/// Hand-rolled all-reduce over `sendrecv_combine_into` at 3/6/12 ranks
/// with per-step storage-id capture: the accumulator starts exclusive, so
/// *every* delivery folds in place and its backing storage survives every
/// combine step of the collective — the posted-receive contract, observed
/// directly rather than through a backend.
#[test]
fn posted_combine_accumulator_storage_survives_every_step() {
    for p in [3usize, 6, 12] {
        let m = 4;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let comb = native_combine();
            let r = c.rank();
            let own = rank_input(r, m);
            let mut acc = Chunk::from_vec(own.clone());
            let acc_id = acc.storage_id();
            c.begin_op();
            let before = c.traffic().copied_bytes;
            for s in 0..p - 1 {
                // Step s: hand own input to rank r+s+1, fold rank
                // r-s-1's incoming copy straight into the accumulator.
                let to = (r + s + 1) % p;
                let from = (r + p - s - 1) % p;
                c.sendrecv_combine_into(
                    to,
                    Chunk::from_slice(&own),
                    from,
                    s as u32,
                    &mut acc,
                    &comb,
                )
                .unwrap();
                assert_eq!(
                    acc.storage_id(),
                    acc_id,
                    "p={p} r={r} step {s}: combine re-materialized the accumulator"
                );
            }
            assert_eq!(
                c.traffic().copied_bytes - before,
                0,
                "p={p} r={r}: combine deliveries must not copy"
            );
            acc.into_vec()
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
        let expect = oracle::all_reduce(&ins);
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o, &expect, "p={p} r={r}");
        }
    }
}

/// Hand-rolled ring rotation over `sendrecv_into` at 3/6/12 ranks: every
/// hop delivers into a posted receive buffer, the exclusive in-flight
/// chunk takes over the posted storage (so `copied_bytes` stays zero),
/// and after p−1 hops each rank holds its successor's input verbatim.
#[test]
fn posted_receive_ring_rotation_matches_oracle() {
    for p in [3usize, 6, 12] {
        let m = 5;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let r = c.rank();
            let mut cur = Chunk::from_vec(rank_input(r, m));
            c.begin_op();
            let before = c.traffic().copied_bytes;
            for s in 0..p - 1 {
                let mut dest = Chunk::from_vec(vec![0.0f32; m]);
                c.sendrecv_into((r + 1) % p, cur, (r + p - 1) % p, s as u32, &mut dest).unwrap();
                cur = dest;
            }
            assert_eq!(
                c.traffic().copied_bytes - before,
                0,
                "p={p} r={r}: posted rotation must not copy"
            );
            cur.to_vec()
        });
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o, &rank_input((r + 1) % p, m), "p={p} r={r}");
        }
    }
}

/// A mis-shaped posted receive fails with the typed
/// [`pccl::error::Error::RecvShapeMismatch`] *without consuming the
/// message*: a correctly-shaped re-post then receives it intact.
#[test]
fn recv_into_shape_mismatch_is_typed_and_repostable() {
    let world = CommWorld::<f32>::new(2);
    let outs = world.run(|c| {
        c.begin_op();
        if c.rank() == 0 {
            c.send_slice(1, 0, Chunk::from_vec(vec![1.0, 2.0, 3.0])).unwrap();
            Vec::new()
        } else {
            let mut small = Chunk::from_vec(vec![0.0f32; 2]);
            let err = c.recv_into(0, 0, &mut small).unwrap_err();
            match err {
                pccl::error::Error::RecvShapeMismatch { expected, got, .. } => {
                    assert_eq!((expected, got), (2, 3));
                }
                other => panic!("expected RecvShapeMismatch, got {other:?}"),
            }
            let mut dest = Chunk::from_vec(vec![0.0f32; 3]);
            c.recv_into(0, 0, &mut dest).unwrap();
            dest.to_vec()
        }
    });
    assert_eq!(outs[1], vec![1.0, 2.0, 3.0]);
}

/// Padding discipline: an unaligned all-reduce must move exactly the bytes
/// of the equivalent aligned (pre-padded) input — the pad-once path adds
/// local copies never, and moved bytes only per the padded schedule.
#[test]
fn padded_all_reduce_moves_no_extra_bytes() {
    let p = 4;
    let bytes_for = |n: usize| -> u64 {
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let before = c.traffic().sent_bytes;
            ring_all_reduce(c, &vec![1.5f32; n], &native_combine()).unwrap();
            c.traffic().sent_bytes - before
        });
        outs.iter().sum()
    };
    // n = 10 pads internally to 12; n = 12 is the aligned reference.
    assert_eq!(bytes_for(10), bytes_for(12));
    // Same through the hierarchical route on an 8-rank 2×4 hierarchy.
    let topo = Topology::new(2, 4, 1).unwrap();
    let hier_bytes_for = move |n: usize| -> u64 {
        let world = CommWorld::<f32>::with_topology(topo);
        let outs = world.run(move |c| {
            let before = c.traffic().sent_bytes;
            hier_all_reduce(c, &vec![0.25f32; n], &native_combine(), InterAlgo::Rec).unwrap();
            c.traffic().sent_bytes - before
        });
        outs.iter().sum()
    };
    // n = 13 pads to 16 on 8 ranks; n = 16 is the aligned reference.
    assert_eq!(hier_bytes_for(13), hier_bytes_for(16));
}
