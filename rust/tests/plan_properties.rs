//! Plan-IR properties: the lowered per-rank schedules are the *same*
//! schedules the closed-form index math in `collectives::schedule`
//! describes, the static verifier rejects forged plans, the verified
//! element totals reproduce the launcher's analytic byte volumes, and the
//! one engine executes plans with chunk-identity preserved end to end.

use pccl::backends::{plan_spec_for, Backend, CollKind};
use pccl::collectives::engine;
use pccl::collectives::oracle;
use pccl::collectives::plan::{self, Algo, Op, PlanKind, PlanSpec};
use pccl::collectives::schedule::{recursive, ring};
use pccl::comm::{Chunk, CommWorld};
use pccl::runtime::expected_schedule_bytes;
use pccl::topology::Topology;

/// Split a plan's op list into rounds (the verifier's cost boundaries):
/// ops between consecutive `Op::Round` markers, `BeginOp`s dropped.
fn rounds(ops: &[Op]) -> Vec<Vec<Op>> {
    let mut out: Vec<Vec<Op>> = Vec::new();
    for op in ops {
        match op {
            Op::Round => out.push(Vec::new()),
            Op::BeginOp { .. } => {}
            other => {
                if let Some(last) = out.last_mut() {
                    last.push(*other);
                }
            }
        }
    }
    out
}

/// Every flat ring plan must replay `schedule::ring` verbatim: same step
/// count, same left/right peers, and the exact send/recv block of every
/// step — for divisible and non-divisible rank counts alike.
#[test]
fn lowered_ring_plans_replay_schedule_index_math() {
    for p in [3usize, 6, 8, 12] {
        let b = 4;
        for r in 0..p {
            let ag = plan::build(&PlanSpec::flat(PlanKind::AllGather, Algo::Ring, p, b, 1), r)
                .unwrap();
            let ag_rounds = rounds(&ag.ops);
            assert_eq!(ag_rounds.len(), ring::steps(p), "p={p} r={r}: AG step count");
            for (s, round) in ag_rounds.iter().enumerate() {
                match round[..] {
                    [Op::SendRecv { send_peer, recv_peer, send_slot, recv_slot, .. }] => {
                        assert_eq!(send_peer, (r + 1) % p, "p={p} r={r} s={s}");
                        assert_eq!(recv_peer, (r + p - 1) % p, "p={p} r={r} s={s}");
                        assert_eq!(send_slot, ring::ag_send_block(r, p, s), "p={p} r={r} s={s}");
                        assert_eq!(recv_slot, ring::ag_recv_block(r, p, s), "p={p} r={r} s={s}");
                    }
                    _ => panic!("p={p} r={r} s={s}: AG round is not one fused exchange"),
                }
            }

            let rs =
                plan::build(&PlanSpec::flat(PlanKind::ReduceScatter, Algo::Ring, p, p * b, 1), r)
                    .unwrap();
            let rs_rounds = rounds(&rs.ops);
            assert_eq!(rs_rounds.len(), ring::steps(p), "p={p} r={r}: RS step count");
            for (s, round) in rs_rounds.iter().enumerate() {
                match round[..] {
                    [Op::SendRecvCombine { send_peer, recv_peer, send_slot, recv_slot, .. }] => {
                        assert_eq!(send_peer, (r + 1) % p, "p={p} r={r} s={s}");
                        assert_eq!(recv_peer, (r + p - 1) % p, "p={p} r={r} s={s}");
                        assert_eq!(send_slot, ring::rs_send_block(r, p, s), "p={p} r={r} s={s}");
                        assert_eq!(recv_slot, ring::rs_recv_block(r, p, s), "p={p} r={r} s={s}");
                    }
                    _ => panic!("p={p} r={r} s={s}: RS round is not one fused combine"),
                }
            }

            // All-reduce = the RS schedule then the AG schedule over the
            // same slots; the phase boundary is the second BeginOp.
            let ar = plan::build(&PlanSpec::flat(PlanKind::AllReduce, Algo::Ring, p, p * b, 1), r)
                .unwrap();
            let ar_rounds = rounds(&ar.ops);
            assert_eq!(ar_rounds.len(), 2 * ring::steps(p), "p={p} r={r}: AR step count");
            for (s, round) in ar_rounds.iter().enumerate() {
                let combining = s < ring::steps(p);
                match round[..] {
                    [Op::SendRecvCombine { .. }] => {
                        assert!(combining, "p={p} r={r} s={s}: combine in the AG phase")
                    }
                    [Op::SendRecv { .. }] => {
                        assert!(!combining, "p={p} r={r} s={s}: plain exchange in the RS phase")
                    }
                    _ => panic!("p={p} r={r} s={s}: AR round shape"),
                }
            }
        }
    }
}

/// Recursive doubling/halving plans must follow `schedule::recursive`:
/// XOR partners, doubling owned ranges on the gather side, and halving
/// volumes (`p / 2^(s+1)` blocks each way) on the scatter side.
#[test]
fn lowered_rec_plans_replay_schedule_index_math() {
    for p in [4usize, 8] {
        let b = 4;
        for r in 0..p {
            let ag = plan::build(&PlanSpec::flat(PlanKind::AllGather, Algo::Rec, p, b, 1), r)
                .unwrap();
            let ag_rounds = rounds(&ag.ops);
            assert_eq!(ag_rounds.len(), recursive::steps(p), "p={p} r={r}: AG step count");
            for (s, round) in ag_rounds.iter().enumerate() {
                let partner = recursive::ag_partner(r, s);
                let (lo, hi) = recursive::ag_owned_range(r, s);
                let (plo, phi) = recursive::ag_owned_range(partner, s);
                let sends: Vec<usize> = round
                    .iter()
                    .filter_map(|op| match *op {
                        Op::Send { peer, slot, .. } => {
                            assert_eq!(peer, partner, "p={p} r={r} s={s}: send partner");
                            Some(slot)
                        }
                        _ => None,
                    })
                    .collect();
                let recvs: Vec<usize> = round
                    .iter()
                    .filter_map(|op| match *op {
                        Op::Recv { peer, slot, .. } => {
                            assert_eq!(peer, partner, "p={p} r={r} s={s}: recv partner");
                            Some(slot)
                        }
                        _ => None,
                    })
                    .collect();
                assert_eq!(sends, (lo..hi).collect::<Vec<_>>(), "p={p} r={r} s={s}: sent blocks");
                assert_eq!(recvs, (plo..phi).collect::<Vec<_>>(), "p={p} r={r} s={s}: got blocks");
            }

            let rs =
                plan::build(&PlanSpec::flat(PlanKind::ReduceScatter, Algo::Rec, p, p * b, 1), r)
                    .unwrap();
            let rs_rounds = rounds(&rs.ops);
            assert_eq!(rs_rounds.len(), recursive::steps(p), "p={p} r={r}: RS step count");
            for (s, round) in rs_rounds.iter().enumerate() {
                let partner = recursive::rs_partner(r, p, s);
                let volume = p / recursive::rs_fraction_denom(s);
                let mut sends = 0;
                let mut folds = 0;
                for op in round {
                    match *op {
                        Op::Send { peer, .. } => {
                            assert_eq!(peer, partner, "p={p} r={r} s={s}: halving partner");
                            sends += 1;
                        }
                        Op::RecvCombine { peer, .. } => {
                            assert_eq!(peer, partner, "p={p} r={r} s={s}: halving partner");
                            folds += 1;
                        }
                        _ => panic!("p={p} r={r} s={s}: unexpected op in halving round"),
                    }
                }
                assert_eq!(sends, volume, "p={p} r={r} s={s}: halving send volume");
                assert_eq!(folds, volume, "p={p} r={r} s={s}: halving fold volume");
            }
        }
    }
}

/// The lockstep verifier is load-bearing: a forged plan set — one rank's
/// final exchange dropped, or one receive rerouted to the wrong peer —
/// must be rejected, while the untampered set passes with the exact
/// schedule volume.
#[test]
fn verifier_rejects_forged_plans() {
    let (p, b) = (4usize, 3usize);
    let spec = PlanSpec::flat(PlanKind::ReduceScatter, Algo::Ring, p, p * b, 1);
    let build_all = || -> Vec<plan::Plan> {
        (0..p).map(|r| plan::build(&spec, r).unwrap()).collect()
    };

    // Baseline: the honest set verifies and moves (p-1)·b elems per rank.
    let stats = plan::verify_plans(&spec, build_all()).unwrap();
    assert_eq!(stats.total_sent_elems, (p * (p - 1) * b) as u64);

    // Forgery 1: drop rank 0's last fused exchange. Its neighbors now
    // wait on a message that is never posted — the simulation must not
    // hang, it must return a typed deadlock/coverage error.
    let mut forged = build_all();
    let last = forged[0]
        .ops
        .iter()
        .rposition(|op| matches!(op, Op::SendRecvCombine { .. }))
        .unwrap();
    forged[0].ops.remove(last);
    assert!(
        plan::verify_plans(&spec, forged).is_err(),
        "a plan with a dropped exchange must not verify"
    );

    // Forgery 2: reroute one receive to the wrong peer.
    let mut forged = build_all();
    for op in forged[2].ops.iter_mut() {
        if let Op::SendRecvCombine { recv_peer, .. } = op {
            *recv_peer = (*recv_peer + 1) % p;
            break;
        }
    }
    assert!(
        plan::verify_plans(&spec, forged).is_err(),
        "a plan with a rerouted receive must not verify"
    );

    // Forgery 3: claim the wrong slot as the output — block coverage must
    // catch a result that is not the rank's reduced block.
    let mut forged = build_all();
    forged[1].outputs = vec![0];
    assert!(
        plan::verify_plans(&spec, forged).is_err(),
        "a plan with a forged output slot must not verify"
    );
}

/// The verifier's element totals are the launcher's analytic byte volumes:
/// for every flat-library cell with a closed form, `verify(spec)` must
/// account for exactly `expected_schedule_bytes` of traffic (f32 cells).
#[test]
fn verified_totals_match_the_closed_form_schedule_bytes() {
    for p in [2usize, 4, 8] {
        let topo = Topology::flat(p);
        for elems in [64usize, 1 << 10] {
            for kind in [CollKind::AllGather, CollKind::ReduceScatter] {
                // Mirror the launcher's §III-A shape convention.
                let input_len = match kind {
                    CollKind::AllGather => (elems / p).max(1),
                    _ => elems.div_ceil(p) * p,
                };
                let spec = plan_spec_for(kind, Backend::Vendor, topo, input_len, 1);
                let stats = plan::verify(&spec).unwrap();
                let expect = expected_schedule_bytes(kind, Backend::Vendor, elems, p)
                    .expect("flat ring cells have a closed form");
                assert_eq!(
                    stats.total_sent_elems * 4,
                    expect,
                    "{} p={p} elems={elems}: verified volume vs closed form",
                    kind.label()
                );
            }
        }
    }
}

/// Chunk identity through the engine: an all-gather block delivered to
/// every rank is the *sender's allocation*, not a copy — the zero-copy
/// contract holds through plan lowering and engine execution, and the
/// engine's results match the oracle.
#[test]
fn engine_executed_plans_preserve_storage_identity() {
    let (p, b) = (4usize, 5usize);
    let spec = PlanSpec::flat(PlanKind::AllGather, Algo::Ring, p, b, 1);
    plan::verify(&spec).unwrap();
    let world = CommWorld::<f32>::new(p);
    let outs = world.run(move |c| {
        let r = c.rank();
        let input = Chunk::from_vec((0..b).map(|i| (r * 100 + i) as f32).collect::<Vec<_>>());
        let my_id = input.storage_id();
        let pl = plan::build(&spec, r).unwrap();
        let blocks = engine::run_flat(c, &pl, vec![input], None).unwrap();
        assert_eq!(blocks.len(), p, "r={r}: one block per rank");
        let ids: Vec<_> = blocks.iter().map(Chunk::storage_id).collect();
        (my_id, ids, Chunk::concat(&blocks))
    });
    let inputs: Vec<Vec<f32>> =
        (0..p).map(|r| (0..b).map(|i| (r * 100 + i) as f32).collect()).collect();
    let expect = oracle::all_gather(&inputs);
    for (r, (_, ids, gathered)) in outs.iter().enumerate() {
        assert_eq!(gathered, &expect, "r={r}: engine result vs oracle");
        for (j, id) in ids.iter().enumerate() {
            assert_eq!(
                *id, outs[j].0,
                "r={r}: block {j} must be rank {j}'s original allocation"
            );
        }
    }
}
