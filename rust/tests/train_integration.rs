//! End-to-end distributed training integration (requires `make artifacts`):
//! DDP and ZeRO-3 must optimize the same trajectory (they are algebraically
//! the same optimizer), backends must agree, and losses must fall.

use pccl::backends::Backend;
use pccl::runtime::Artifacts;
use pccl::topology::Topology;
use pccl::train::{ddp::run_ddp, zero3::run_zero3, DdpConfig, Zero3Config};

fn have_artifacts() -> bool {
    if Artifacts::load_default().is_err() {
        eprintln!("skipping: run `make artifacts` first");
        return false;
    }
    true
}

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn ddp_loss_decreases_and_is_synchronized() {
    if !have_artifacts() {
        return;
    }
    let report = run_ddp(&DdpConfig {
        ranks: 2,
        steps: 6,
        lr: 0.5,
        backend: Backend::PcclRec,
        seed: 11,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(report.losses.len(), 6);
    assert!(report.final_loss() < report.initial_loss());
}

#[test]
fn ddp_and_zero3_follow_the_same_trajectory() {
    if !have_artifacts() {
        return;
    }
    let ddp = run_ddp(&DdpConfig {
        ranks: 2,
        steps: 5,
        lr: 0.5,
        momentum: 0.0,
        backend: Backend::PcclRec,
        seed: 5,
        ..Default::default()
    })
    .unwrap();
    let z3 = run_zero3(&Zero3Config {
        ranks: 2,
        steps: 5,
        lr: 0.5,
        momentum: 0.0,
        backend: Backend::PcclRec,
        seed: 5,
        ..Default::default()
    })
    .unwrap();
    for (i, (a, b)) in ddp.losses.iter().zip(&z3.losses).enumerate() {
        assert!(
            close(*a, *b, 1e-3),
            "step {i}: ddp {a} vs zero3 {b} (sharded ≠ replicated update?)"
        );
    }
}

#[test]
fn backends_produce_equivalent_training() {
    if !have_artifacts() {
        return;
    }
    let run = |backend| {
        run_ddp(&DdpConfig {
            ranks: 4,
            topology: Some(Topology::new(2, 2, 1).unwrap()),
            steps: 4,
            lr: 0.5,
            backend,
            seed: 3,
            ..Default::default()
        })
        .unwrap()
        .losses
    };
    let vendor = run(Backend::Vendor);
    let rec = run(Backend::PcclRec);
    let ring = run(Backend::PcclRing);
    for i in 0..vendor.len() {
        assert!(
            close(vendor[i], rec[i], 1e-3) && close(vendor[i], ring[i], 1e-3),
            "step {i}: vendor {} rec {} ring {}",
            vendor[i],
            rec[i],
            ring[i]
        );
    }
}

#[test]
fn zero3_shards_cover_all_params() {
    if !have_artifacts() {
        return;
    }
    let report = run_zero3(&Zero3Config {
        ranks: 3, // non-divisible param count → padding path
        steps: 2,
        ..Default::default()
    })
    .unwrap();
    assert!(report.shard_elems * 3 >= report.param_count);
    assert!(report.shard_elems * 3 < report.param_count + 3);
}
