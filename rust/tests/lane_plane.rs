//! The multi-lane striped transport, end to end:
//!
//! * **Lane isolation** — traffic on lane `l` must never satisfy a
//!   receive on lane `l' != l`, even when the `(peer, op, step)` triple is
//!   identical: each lane has its own queue and its id is folded into the
//!   wire tag. The stale-lane probe is the wire-tag regression for the
//!   lane dimension (mirroring the op-seq freshness probes of the chunked
//!   plane).
//! * **Striped collectives ≡ oracle** — lane-parallel ring and
//!   hierarchical RS/AG/AR over 3/6/12 ranks with stripe splits that are
//!   uneven against the lane count (and zero-length when the block is
//!   shorter than the lane count), concatenating to exactly the unstriped
//!   result.
//! * **Per-lane accounting** — a striped run through the dispatch layer
//!   moves bytes on *every* lane, and the per-lane counters sum to the
//!   endpoint totals the single-lane guards already check.

use pccl::backends::{
    all_gather_lanes_chunks, all_reduce_lanes_chunks, reduce_scatter_stripes, Backend,
    CollectiveOptions, MIN_STRIPE_ELEMS,
};
use pccl::collectives::{
    hier_all_gather_lanes_chunks, hier_all_reduce_lanes_chunks, hier_reduce_scatter_lanes_chunks,
    oracle, ring_all_gather_lanes_chunks, ring_all_reduce_lanes_chunks,
    ring_reduce_scatter_lanes_chunks, InterAlgo,
};
use pccl::comm::{stripe_lens, Chunk, Comm, CommWorld};
use pccl::reduction::offload::native_combine;
use pccl::topology::Topology;

fn rank_input(r: usize, len: usize) -> Vec<f32> {
    (0..len).map(|i| (r * 1000 + i) as f32).collect()
}

/// Same `(peer, step)` posted on three lanes at once, received in
/// *reverse* lane order: each receive must pull its own lane's payload.
/// A shared queue (or a tag that failed to fold the lane id) would hand
/// the first receive lane 0's message FIFO-style.
#[test]
fn lane_views_deliver_per_lane_despite_identical_steps() {
    let lanes = 3;
    let world = CommWorld::<f32>::new(2).with_lanes(lanes);
    let outs = world.run(move |c| {
        c.begin_op();
        if c.rank() == 0 {
            for l in 0..lanes {
                c.lane_comm(l)
                    .unwrap()
                    .send_slice(1, 0, Chunk::from_vec(vec![(100 * l) as f32; 2]))
                    .unwrap();
            }
            Vec::new()
        } else {
            let mut got = vec![Vec::new(); lanes];
            for l in (0..lanes).rev() {
                got[l] = c.lane_comm(l).unwrap().recv_chunk(0, 0).unwrap().to_vec();
            }
            got
        }
    });
    for l in 0..lanes {
        assert_eq!(
            outs[1][l],
            vec![(100 * l) as f32; 2],
            "lane {l} received another lane's payload"
        );
    }
}

/// Stale-lane wire-tag regression: an unreceived message parked on lane 1
/// must not be matched by a later lane-0 exchange using the same step, and
/// must still be waiting — intact — on its own lane afterwards.
#[test]
fn stale_lane_message_never_satisfies_another_lane() {
    let world = CommWorld::<f32>::new(1).with_lanes(2);
    let outs = world.run(|c| {
        c.begin_op();
        // Stale message on lane 1, deliberately not received.
        c.lane_comm(1)
            .unwrap()
            .send_slice(0, 7, Chunk::from_vec(vec![111.0]))
            .unwrap();
        // Fresh self-exchange on lane 0 with the identical step: if lane
        // ids leaked out of the wire tag or the queues were shared, this
        // receive would match the stale 111.
        let fresh = {
            let mut l0 = c.lane_comm(0).unwrap();
            l0.send_slice(0, 7, Chunk::from_vec(vec![222.0])).unwrap();
            l0.recv_chunk(0, 7).unwrap().to_vec()
        };
        // And the stale message still sits on lane 1, undamaged.
        let stale = c.lane_comm(1).unwrap().recv_chunk(0, 7).unwrap().to_vec();
        (fresh, stale)
    });
    assert_eq!(outs[0].0, vec![222.0], "lane 0 matched a lane-1 message");
    assert_eq!(outs[0].1, vec![111.0], "lane 1's message must survive untouched");
}

/// Striped flat-ring RS/AG/AR ≡ oracle at 3 ranks with a prime block
/// length (uneven against every stripe split), including the padded
/// all-reduce length, with the reduce path staying copy-free.
#[test]
fn striped_ring_collectives_match_oracle_uneven_stripes() {
    let p = 3;
    let b = 7; // stripe_lens(7, 2) = [4, 3] — uneven
    let k = 2;
    let n_ar = 2 * p + 1; // never a multiple of p → padded path
    let world = CommWorld::<f32>::new(p).with_lanes(k);
    let outs = world.run(move |c| {
        let comb = native_combine();
        let r = c.rank();
        let before = c.traffic().copied_bytes;
        let rs =
            ring_reduce_scatter_lanes_chunks(c, Chunk::from_vec(rank_input(r, p * b)), &comb, k)
                .unwrap();
        assert_eq!(
            rs.iter().map(Chunk::len).collect::<Vec<_>>(),
            stripe_lens(b, k),
            "r={r}: RS stripe shapes must follow the wire contract"
        );
        let ag =
            ring_all_gather_lanes_chunks(c, Chunk::from_vec(rank_input(r, b)), k).unwrap();
        assert_eq!(ag.len(), p * k, "r={r}: AG must return rank-major stripe lists");
        let ar =
            ring_all_reduce_lanes_chunks(c, Chunk::from_vec(rank_input(r, n_ar)), &comb, k)
                .unwrap();
        let copied = c.traffic().copied_bytes - before;
        assert_eq!(copied, 0, "r={r}: striped reduce deliveries must not copy");
        (Chunk::concat(&rs), Chunk::concat(&ag), Chunk::concat(&ar))
    });
    let rs_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
    let ag_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, b)).collect();
    let ar_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, n_ar)).collect();
    for (r, (rs, ag, ar)) in outs.iter().enumerate() {
        assert_eq!(rs, &oracle::reduce_scatter(&rs_ins, r), "rs r={r}");
        assert_eq!(ag, &oracle::all_gather(&ag_ins), "ag r={r}");
        assert_eq!(ar.len(), n_ar, "ar r={r}: trim must drop the padding");
        assert_eq!(ar, &oracle::all_reduce(&ar_ins), "ar r={r}");
    }
}

/// Striped hierarchical RS/AG/AR ≡ oracle at 6 (3×2) and 12 (3×4) ranks —
/// non-power-of-two node counts over the striped inter-node ring.
#[test]
fn striped_hier_collectives_match_oracle_on_non_pow2_ranks() {
    for topo in [
        Topology::new(3, 2, 1).unwrap(), // 6 ranks
        Topology::new(3, 4, 1).unwrap(), // 12 ranks
    ] {
        let p = topo.world_size();
        let b = 7;
        let k = 2;
        let n_ar = 2 * p + 1;
        let world = CommWorld::<f32>::with_topology(topo).with_lanes(k);
        let outs = world.run(move |c| {
            let comb = native_combine();
            let r = c.rank();
            let rs = hier_reduce_scatter_lanes_chunks(
                c,
                Chunk::from_vec(rank_input(r, p * b)),
                &comb,
                InterAlgo::Ring,
                k,
            )
            .unwrap();
            let ag = hier_all_gather_lanes_chunks(
                c,
                Chunk::from_vec(rank_input(r, b)),
                InterAlgo::Ring,
                k,
            )
            .unwrap();
            let ar = hier_all_reduce_lanes_chunks(
                c,
                Chunk::from_vec(rank_input(r, n_ar)),
                &comb,
                InterAlgo::Ring,
                k,
            )
            .unwrap();
            (Chunk::concat(&rs), Chunk::concat(&ag), Chunk::concat(&ar))
        });
        let rs_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
        let ag_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, b)).collect();
        let ar_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, n_ar)).collect();
        for (r, (rs, ag, ar)) in outs.iter().enumerate() {
            assert_eq!(rs, &oracle::reduce_scatter(&rs_ins, r), "p={p} rs r={r}");
            assert_eq!(ag, &oracle::all_gather(&ag_ins), "p={p} ag r={r}");
            assert_eq!(ar.len(), n_ar, "p={p} ar r={r}: trim must drop the padding");
            assert_eq!(ar, &oracle::all_reduce(&ar_ins), "p={p} ar r={r}");
        }
    }
}

/// Blocks shorter than the lane count produce zero-length tail stripes
/// (the shape contract keeps lane schedules aligned); the collectives must
/// still match the oracle with empty stripes riding their lanes.
#[test]
fn zero_length_stripes_keep_lane_schedules_aligned() {
    let p = 3;
    let b = 3; // stripe_lens(3, 4) = [1, 1, 1, 0]
    let k = 4;
    assert_eq!(stripe_lens(b, k), vec![1, 1, 1, 0]);
    let world = CommWorld::<f32>::new(p).with_lanes(k);
    let outs = world.run(move |c| {
        let comb = native_combine();
        let r = c.rank();
        let rs =
            ring_reduce_scatter_lanes_chunks(c, Chunk::from_vec(rank_input(r, p * b)), &comb, k)
                .unwrap();
        assert_eq!(rs.len(), k, "r={r}: every lane owns a stripe, even empty ones");
        assert_eq!(rs[k - 1].len(), 0, "r={r}: the tail stripe must be empty");
        let ag = ring_all_gather_lanes_chunks(c, Chunk::from_vec(rank_input(r, b)), k).unwrap();
        assert_eq!(ag.len(), p * k);
        (Chunk::concat(&rs), Chunk::concat(&ag))
    });
    let rs_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
    let ag_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, b)).collect();
    for (r, (rs, ag)) in outs.iter().enumerate() {
        assert_eq!(rs, &oracle::reduce_scatter(&rs_ins, r), "rs r={r}");
        assert_eq!(ag, &oracle::all_gather(&ag_ins), "ag r={r}");
    }
}

/// The dispatch-layer striped entry points on a multi-lane world: every
/// lane moves bytes, the per-lane counters sum to the endpoint totals,
/// and results still match the oracle. (Payload sized so the per-stripe
/// length clears [`MIN_STRIPE_ELEMS`] and striping genuinely engages.)
#[test]
fn dispatch_striped_paths_move_bytes_on_every_lane() {
    let p = 4;
    let k = 2;
    let b = k * MIN_STRIPE_ELEMS; // per-stripe block length stays at the floor
    let world = CommWorld::<f32>::new(p).with_lanes(k);
    let outs = world.run(move |c| {
        let opts = CollectiveOptions::default().backend(Backend::PcclRing).lanes(k);
        let r = c.rank();
        let before_total = c.traffic();
        let before: Vec<u64> = c.traffic_per_lane().iter().map(|t| t.sent_bytes).collect();
        let rs = reduce_scatter_stripes(c, Chunk::from_vec(rank_input(r, p * b)), &opts).unwrap();
        assert_eq!(rs.len(), k, "r={r}: dispatch layer must keep {k} stripes");
        let ag = all_gather_lanes_chunks(c, Chunk::from_vec(rank_input(r, b)), &opts).unwrap();
        let ar = all_reduce_lanes_chunks(c, Chunk::from_vec(rank_input(r, p * b)), &opts).unwrap();
        let after: Vec<u64> = c.traffic_per_lane().iter().map(|t| t.sent_bytes).collect();
        let per_lane: Vec<u64> = after.iter().zip(&before).map(|(a, b)| a - b).collect();
        assert_eq!(per_lane.len(), k);
        for (l, moved) in per_lane.iter().enumerate() {
            assert!(*moved > 0, "r={r}: lane {l} moved no bytes on a striped run");
        }
        assert_eq!(
            per_lane.iter().sum::<u64>(),
            c.traffic().sent_bytes - before_total.sent_bytes,
            "r={r}: per-lane counters must sum to the endpoint total"
        );
        (Chunk::concat(&rs), Chunk::concat(&ag), Chunk::concat(&ar))
    });
    let rs_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
    let ag_ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, b)).collect();
    for (r, (rs, ag, ar)) in outs.iter().enumerate() {
        assert_eq!(rs, &oracle::reduce_scatter(&rs_ins, r), "rs r={r}");
        assert_eq!(ag, &oracle::all_gather(&ag_ins), "ag r={r}");
        assert_eq!(ar, &oracle::all_reduce(&rs_ins), "ar r={r}");
    }
}
