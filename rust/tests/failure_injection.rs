//! Failure injection: dead ranks, malformed buffers, missing/corrupt
//! artifacts — every failure must surface as a typed error, never a hang.
//! The abort-protocol tests at the bottom assert the *bounded-time* part:
//! injected faults must turn into [`Error::CollectiveAborted`] on every
//! surviving rank within seconds, far under the 60 s default receive
//! timeout.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use pccl::backends::{all_gather, all_reduce, reduce_scatter, Backend, CollectiveOptions};
use pccl::comm::{Chunk, Comm, CommWorld, Communicator, FaultAction, FaultPlan, FaultSpec};
use pccl::error::Error;
use pccl::runtime::{Artifacts, DeviceService, PersistentWorld, TrialReport};
use pccl::topology::Topology;
use pccl::util::tmp::TempDir;

#[test]
fn dead_rank_times_out_cleanly() {
    // Rank 1 exits immediately; the others' ring all-gather must fail with
    // RecvTimeout (or TransportClosed), not deadlock.
    let world = CommWorld::<f32>::new(3);
    let outs = world.run(|c| {
        c.set_timeout(Duration::from_millis(100));
        if c.rank() == 1 {
            return Ok(Vec::new()); // dies before participating
        }
        let opts = CollectiveOptions::default().backend(Backend::Vendor);
        all_gather(c, &[1.0, 2.0], &opts)
    });
    assert!(outs[1].as_ref().unwrap().is_empty());
    for r in [0, 2] {
        match &outs[r] {
            Err(Error::RecvTimeout { .. }) | Err(Error::TransportClosed { .. }) => {}
            other => panic!("rank {r}: expected timeout, got {other:?}"),
        }
    }
}

#[test]
fn slow_rank_is_not_a_failure() {
    // A rank that is merely slow (sleeps) must not trip others' timeouts
    // when the timeout budget is generous.
    let world = CommWorld::<f32>::new(4);
    let outs = world.run(|c| {
        if c.rank() == 2 {
            std::thread::sleep(Duration::from_millis(50));
        }
        let opts = CollectiveOptions::default().backend(Backend::PcclRec);
        all_gather(c, &[c.rank() as f32], &opts)
    });
    for o in outs {
        assert_eq!(o.unwrap(), vec![0.0, 1.0, 2.0, 3.0]);
    }
}

#[test]
fn bad_buffer_sizes_are_rejected_not_hung() {
    let world = CommWorld::<f32>::new(4);
    let outs = world.run(|c| {
        let opts = CollectiveOptions::default().backend(Backend::PcclRing);
        // 7 elements not divisible by 4 ranks.
        reduce_scatter(c, &[0.0; 7], &opts)
    });
    for o in outs {
        match o {
            Err(Error::BadBufferSize { len: 7, .. }) => {}
            other => panic!("expected BadBufferSize, got {other:?}"),
        }
    }
}

#[test]
fn empty_input_rejected() {
    let world = CommWorld::<f32>::new(2);
    let outs = world.run(|c| {
        let opts = CollectiveOptions::default();
        all_gather(c, &[], &opts)
    });
    assert!(outs.iter().all(|o| o.is_err()));
}

#[test]
fn mismatched_topology_is_rejected() {
    // Communicator construction validates topology vs transport size.
    let (_hub, mut eps) = pccl::comm::TransportHub::<f32>::new(4);
    let ep = eps.remove(0);
    match pccl::comm::Communicator::new(ep, Topology::flat(8)) {
        Err(Error::InvalidTopology(_)) => {}
        Err(other) => panic!("expected InvalidTopology, got {other}"),
        Ok(_) => panic!("mismatched topology accepted"),
    }
}

#[test]
fn missing_artifact_dir_is_actionable() {
    let err = Artifacts::load("/no/such/dir").unwrap_err();
    assert!(err.to_string().contains("make artifacts"));
}

#[test]
fn truncated_hlo_artifact_fails_at_compile_not_hang() {
    let dir = TempDir::new().unwrap();
    std::fs::write(
        dir.path().join("manifest.json"),
        r#"{"version":1,"entries":{"broken":{"file":"broken.hlo.txt",
            "inputs":[{"shape":[4],"dtype":"f32"}],
            "outputs":[{"shape":[4],"dtype":"f32"}]}}}"#,
    )
    .unwrap();
    std::fs::write(dir.path().join("broken.hlo.txt"), "HloModule broken, entry").unwrap();
    let arts = Artifacts::load(dir.path()).unwrap();
    let service = DeviceService::spawn(arts).unwrap();
    let err = service.handle().preload(&["broken"]).unwrap_err();
    assert!(matches!(err, Error::Xla(_)), "got {err:?}");
}

#[test]
fn unknown_artifact_name_is_typed() {
    let dir = TempDir::new().unwrap();
    std::fs::write(dir.path().join("manifest.json"), r#"{"version":1,"entries":{}}"#).unwrap();
    let arts = Artifacts::load(dir.path()).unwrap();
    let service = DeviceService::spawn(arts).unwrap();
    let err = service.handle().execute("nope", vec![]).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "got {err:?}");
}

#[test]
fn corrupt_manifest_json_is_typed() {
    let dir = TempDir::new().unwrap();
    std::fs::write(dir.path().join("manifest.json"), "{not json").unwrap();
    let err = Artifacts::load(dir.path()).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)));
    assert!(err.to_string().contains("malformed"));
}

/// A kill-rank plan naming every peer of the victim, so the latch engages
/// on the victim's first send no matter which neighbor its schedule
/// touches first.
fn kill_rank_plan(victim: usize, ranks: usize) -> FaultPlan {
    FaultPlan::new(
        (0..ranks)
            .filter(|&peer| peer != victim)
            .map(|peer| FaultSpec {
                rank: victim,
                peer,
                lane: 0,
                op_seq: 0,
                action: FaultAction::KillRank,
            })
            .collect(),
    )
}

#[test]
fn killed_rank_aborts_every_peer_within_the_bound() {
    // Rank 1 dies on its first send and never broadcasts (a dead host
    // can't). Peers must detect it via their (short) receive timeout, and
    // the engine must convert that into the typed collective abort on
    // EVERY rank — wall-clock bounded, not 60 s of default timeout.
    let world = CommWorld::<f32>::new(4)
        .with_abort()
        .with_recv_timeout(Duration::from_millis(200))
        .with_fault_plan(kill_rank_plan(1, 4));
    let t = Instant::now();
    let outs = world.run(|c| {
        let opts = CollectiveOptions::default().backend(Backend::PcclRing);
        all_gather(c, &[c.rank() as f32; 64], &opts)
    });
    assert!(
        t.elapsed() < Duration::from_secs(10),
        "abort detection took {:?} — the bound does not hold",
        t.elapsed()
    );
    for (r, out) in outs.iter().enumerate() {
        match out {
            Err(Error::CollectiveAborted { .. }) => {}
            other => panic!("rank {r}: expected CollectiveAborted, got {other:?}"),
        }
    }
}

#[test]
fn persistent_world_survives_killed_rank_and_recomputes() {
    // Trial 1 aborts via the kill latch; the world must resync (not
    // poison) and trial 2 must produce the exact faultless result.
    let mut world = PersistentWorld::<f32>::new(Topology::flat(4)).unwrap();
    world.set_trial_deadline(Duration::from_secs(10));
    let plan = kill_rank_plan(0, 4);
    let t = Instant::now();
    let err = world
        .run_trial(move |c: &mut Communicator<f32>| {
            c.set_timeout(Duration::from_millis(200));
            c.arm_faults(plan.clone());
            let opts = CollectiveOptions::default().backend(Backend::PcclRec);
            let out = all_reduce(c, &[1.0f32; 32], &opts);
            c.clear_faults();
            out.map(|v| TrialReport {
                checksum: v.iter().map(|&x| f64::from(x)).sum(),
                ..Default::default()
            })
        })
        .unwrap_err();
    assert!(matches!(err, Error::CollectiveAborted { .. }), "got {err:?}");
    assert!(t.elapsed() < Duration::from_secs(10));
    assert!(!world.is_poisoned(), "typed aborts must be recoverable");
    let reports = world
        .run_trial(|c: &mut Communicator<f32>| {
            let opts = CollectiveOptions::default().backend(Backend::PcclRec);
            let out = all_reduce(c, &[1.0f32; 32], &opts)?;
            Ok(TrialReport {
                checksum: out.iter().map(|&x| f64::from(x)).sum(),
                ..Default::default()
            })
        })
        .unwrap();
    for r in &reports {
        assert_eq!(r.checksum, 128.0); // 32 ones summed over 4 ranks
    }
}

#[test]
fn survivors_shrink_around_a_dead_rank_and_finish() {
    // Full recovery arc on one world: a rank goes silent, a survivor
    // detects by timeout and broadcasts, the token is cleared, and the
    // survivors rebuild a 2-rank world that completes a correct exchange.
    let p = 3;
    let dead = 2usize;
    let b_all = Arc::new(Barrier::new(p));
    let b_live = Arc::new(Barrier::new(p - 1));
    let world = CommWorld::<f32>::new(p)
        .with_abort()
        .with_recv_timeout(Duration::from_millis(200));
    let t = Instant::now();
    let outs = world.run(move |c: &mut Communicator<f32>| -> Result<f32, Error> {
        let (r, p) = (c.rank(), c.size());
        if r == dead {
            b_all.wait(); // keeps its endpoint alive through detection
            return Ok(0.0);
        }
        c.begin_op();
        c.send_slice((r + 1) % p, 0, Chunk::from_vec(vec![r as f32]))?;
        match c.recv_chunk((r + p - 1) % p, 0) {
            Ok(_) | Err(Error::CollectiveAborted { .. }) => {}
            Err(e) => c.broadcast_abort(&e.to_string()),
        }
        b_all.wait();
        if r == 0 {
            c.abort_token().expect("armed").clear();
        }
        b_live.wait();
        let mut sub = c.shrink(&[dead])?;
        sub.begin_op();
        let (sp, sr) = (sub.size(), sub.rank());
        sub.send_slice((sr + 1) % sp, 0, Chunk::from_vec(vec![r as f32]))?;
        Ok(sub.recv_chunk((sr + sp - 1) % sp, 0)?[0])
    });
    assert!(t.elapsed() < Duration::from_secs(10));
    let got: f32 = outs[0].as_ref().unwrap() + outs[1].as_ref().unwrap();
    assert_eq!(got, 1.0, "survivor ring must carry ranks 0 and 1");
}

#[test]
fn poisoned_world_tears_down_promptly() {
    // A rank panic poisons the world; dropping it must still join every
    // rank thread instead of hanging on the dead one.
    let mut world = PersistentWorld::<f32>::new(Topology::flat(2)).unwrap();
    world.set_trial_deadline(Duration::from_millis(300));
    let _ = world.run_trial(|c: &mut Communicator<f32>| {
        if c.rank() == 0 {
            panic!("simulated crash");
        }
        Ok(TrialReport::default())
    });
    assert!(world.is_poisoned());
    let t = Instant::now();
    drop(world);
    assert!(t.elapsed() < Duration::from_secs(5), "teardown hung on a dead rank");
}

#[test]
fn peer_out_of_range_detected() {
    let world = CommWorld::<f32>::new(2);
    let outs = world.run(|c| {
        c.begin_op();
        c.send_slice(5, 0, Chunk::from_vec(vec![1.0]))
    });
    for o in outs {
        assert!(matches!(o, Err(Error::PeerOutOfRange { peer: 5, size: 2 })));
    }
}
