//! Failure injection: dead ranks, malformed buffers, missing/corrupt
//! artifacts — every failure must surface as a typed error, never a hang.

use std::time::Duration;

use pccl::backends::{all_gather, reduce_scatter, Backend, CollectiveOptions};
use pccl::comm::{Chunk, Comm, CommWorld};
use pccl::error::Error;
use pccl::runtime::{Artifacts, DeviceService};
use pccl::topology::Topology;
use pccl::util::tmp::TempDir;

#[test]
fn dead_rank_times_out_cleanly() {
    // Rank 1 exits immediately; the others' ring all-gather must fail with
    // RecvTimeout (or TransportClosed), not deadlock.
    let world = CommWorld::<f32>::new(3);
    let outs = world.run(|c| {
        c.set_timeout(Duration::from_millis(100));
        if c.rank() == 1 {
            return Ok(Vec::new()); // dies before participating
        }
        let opts = CollectiveOptions::default().backend(Backend::Vendor);
        all_gather(c, &[1.0, 2.0], &opts)
    });
    assert!(outs[1].as_ref().unwrap().is_empty());
    for r in [0, 2] {
        match &outs[r] {
            Err(Error::RecvTimeout { .. }) | Err(Error::TransportClosed { .. }) => {}
            other => panic!("rank {r}: expected timeout, got {other:?}"),
        }
    }
}

#[test]
fn slow_rank_is_not_a_failure() {
    // A rank that is merely slow (sleeps) must not trip others' timeouts
    // when the timeout budget is generous.
    let world = CommWorld::<f32>::new(4);
    let outs = world.run(|c| {
        if c.rank() == 2 {
            std::thread::sleep(Duration::from_millis(50));
        }
        let opts = CollectiveOptions::default().backend(Backend::PcclRec);
        all_gather(c, &[c.rank() as f32], &opts)
    });
    for o in outs {
        assert_eq!(o.unwrap(), vec![0.0, 1.0, 2.0, 3.0]);
    }
}

#[test]
fn bad_buffer_sizes_are_rejected_not_hung() {
    let world = CommWorld::<f32>::new(4);
    let outs = world.run(|c| {
        let opts = CollectiveOptions::default().backend(Backend::PcclRing);
        // 7 elements not divisible by 4 ranks.
        reduce_scatter(c, &[0.0; 7], &opts)
    });
    for o in outs {
        match o {
            Err(Error::BadBufferSize { len: 7, .. }) => {}
            other => panic!("expected BadBufferSize, got {other:?}"),
        }
    }
}

#[test]
fn empty_input_rejected() {
    let world = CommWorld::<f32>::new(2);
    let outs = world.run(|c| {
        let opts = CollectiveOptions::default();
        all_gather(c, &[], &opts)
    });
    assert!(outs.iter().all(|o| o.is_err()));
}

#[test]
fn mismatched_topology_is_rejected() {
    // Communicator construction validates topology vs transport size.
    let (_hub, mut eps) = pccl::comm::TransportHub::<f32>::new(4);
    let ep = eps.remove(0);
    match pccl::comm::Communicator::new(ep, Topology::flat(8)) {
        Err(Error::InvalidTopology(_)) => {}
        Err(other) => panic!("expected InvalidTopology, got {other}"),
        Ok(_) => panic!("mismatched topology accepted"),
    }
}

#[test]
fn missing_artifact_dir_is_actionable() {
    let err = Artifacts::load("/no/such/dir").unwrap_err();
    assert!(err.to_string().contains("make artifacts"));
}

#[test]
fn truncated_hlo_artifact_fails_at_compile_not_hang() {
    let dir = TempDir::new().unwrap();
    std::fs::write(
        dir.path().join("manifest.json"),
        r#"{"version":1,"entries":{"broken":{"file":"broken.hlo.txt",
            "inputs":[{"shape":[4],"dtype":"f32"}],
            "outputs":[{"shape":[4],"dtype":"f32"}]}}}"#,
    )
    .unwrap();
    std::fs::write(dir.path().join("broken.hlo.txt"), "HloModule broken, entry").unwrap();
    let arts = Artifacts::load(dir.path()).unwrap();
    let service = DeviceService::spawn(arts).unwrap();
    let err = service.handle().preload(&["broken"]).unwrap_err();
    assert!(matches!(err, Error::Xla(_)), "got {err:?}");
}

#[test]
fn unknown_artifact_name_is_typed() {
    let dir = TempDir::new().unwrap();
    std::fs::write(dir.path().join("manifest.json"), r#"{"version":1,"entries":{}}"#).unwrap();
    let arts = Artifacts::load(dir.path()).unwrap();
    let service = DeviceService::spawn(arts).unwrap();
    let err = service.handle().execute("nope", vec![]).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)), "got {err:?}");
}

#[test]
fn corrupt_manifest_json_is_typed() {
    let dir = TempDir::new().unwrap();
    std::fs::write(dir.path().join("manifest.json"), "{not json").unwrap();
    let err = Artifacts::load(dir.path()).unwrap_err();
    assert!(matches!(err, Error::Artifact(_)));
    assert!(err.to_string().contains("malformed"));
}

#[test]
fn peer_out_of_range_detected() {
    let world = CommWorld::<f32>::new(2);
    let outs = world.run(|c| {
        c.begin_op();
        c.send_slice(5, 0, Chunk::from_vec(vec![1.0]))
    });
    for o in outs {
        assert!(matches!(o, Err(Error::PeerOutOfRange { peer: 5, size: 2 })));
    }
}
