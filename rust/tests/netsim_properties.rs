//! Netsim invariants and paper-shape checks: byte conservation, routing
//! signatures, monotonicity, regime crossovers, and dispatcher quality —
//! the properties that make the simulated figures trustworthy.

use pccl::backends::{Backend, CollKind};
use pccl::dispatch::{Dataset, SvmDispatcher};
use pccl::netsim::counters::PACKET_BYTES;
use pccl::netsim::libmodel::{schedule, simulate, LibModel};
use pccl::topology::Machine;
use pccl::util::prop::check;
use pccl::util::rng::Rng;

const MB: usize = 1 << 20;

fn rand_cfg(rng: &mut Rng) -> (usize, usize) {
    let msg = (1 << rng.range_usize(20, 31)) as usize; // 1 MB .. 1 GB
    let ranks = 8 << rng.range_usize(0, 9); // 8 .. 2048
    (msg, ranks)
}

#[test]
fn prop_counters_conserve_inter_node_volume() {
    // For ring-based all-gather, the total posted bytes per node must be
    // ~the algorithm's analytic inter-node volume: steps · block.
    check("byte conservation", 20, 0xC0, |rng| {
        let (msg, ranks) = rand_cfg(rng);
        for lib in [LibModel::Vendor, LibModel::CrayMpich, LibModel::Custom] {
            let (_, counters, _) =
                schedule(Machine::Frontier, lib, CollKind::AllGather, msg, ranks).unwrap();
            let posted_bytes = counters.total_posted() * PACKET_BYTES;
            let expect = (ranks - 1) as f64 * (msg as f64 / ranks as f64);
            let rel = (posted_bytes - expect).abs() / expect;
            assert!(rel < 1e-6, "{lib:?}: posted {posted_bytes} vs {expect}");
            // Reads mirror writes.
            let read_bytes = counters.total_non_posted() * PACKET_BYTES;
            assert!((read_bytes - expect).abs() / expect < 1e-6);
        }
    });
}

#[test]
fn prop_routing_signatures() {
    // Observation 1's counter signatures hold for every configuration:
    // Cray-MPICH single-NIC, vendor and PCCL even.
    check("routing signatures", 16, 0xC1, |rng| {
        let (msg, ranks) = rand_cfg(rng);
        let (_, cray, _) =
            schedule(Machine::Frontier, LibModel::CrayMpich, CollKind::AllGather, msg, ranks)
                .unwrap();
        assert!(cray.posted_pkts[0] > 0.0);
        assert!(cray.posted_pkts[1..].iter().all(|&v| v == 0.0));
        assert!(cray.non_posted_pkts[3] > 0.0);
        assert!(cray.non_posted_pkts[..3].iter().all(|&v| v == 0.0));
        let (_, c, _) =
            schedule(Machine::Frontier, LibModel::Vendor, CollKind::AllGather, msg, ranks)
                .unwrap();
        assert!((c.posted_imbalance() - 1.0).abs() < 1e-6);
        // PCCL spreads inter-node traffic evenly — meaningful only with
        // more than one node (below that there is no inter-node traffic).
        if ranks > 8 {
            for lib in [LibModel::PcclRing, LibModel::PcclRec] {
                let (_, c, _) =
                    schedule(Machine::Frontier, lib, CollKind::AllGather, msg, ranks).unwrap();
                assert!(
                    (c.posted_imbalance() - 1.0).abs() < 1e-6,
                    "{lib:?} imbalance {}",
                    c.posted_imbalance()
                );
            }
        }
    });
}

#[test]
fn prop_times_monotone_in_message_size() {
    check("monotone in msg", 16, 0xC2, |rng| {
        let ranks = 8 << rng.range_usize(0, 9);
        let kind = [CollKind::AllGather, CollKind::ReduceScatter, CollKind::AllReduce]
            [rng.range_usize(0, 3)];
        for lib in [
            LibModel::Vendor,
            LibModel::CrayMpich,
            LibModel::PcclRing,
            LibModel::PcclRec,
        ] {
            let mut prev = 0.0;
            for mb in [1usize, 8, 64, 512] {
                let t = simulate(Machine::Frontier, lib, kind, mb * MB, ranks, 1, 1)
                    .unwrap()
                    .stats
                    .mean();
                assert!(
                    t >= prev,
                    "{lib:?} {kind:?} p={ranks}: t({mb} MB)={t} < {prev}"
                );
                prev = t;
            }
        }
    });
}

#[test]
fn prop_vendor_latency_grows_linearly_pccl_log() {
    // Fig 1 / Obs 2: flat-ring latency term is linear in p; PCCL's is
    // logarithmic — so the ratio t(4p)/t(p) at small message must be ≈4×
    // larger for vendor than for PCCL_rec.
    check("latency scaling", 8, 0xC3, |rng| {
        let p0 = 64 << rng.range_usize(0, 3);
        let msg = 1 * MB; // latency-dominated
        let t = |lib, p| {
            simulate(Machine::Frontier, lib, CollKind::AllGather, msg, p, 1, 1)
                .unwrap()
                .stats
                .mean()
        };
        let vendor_growth = t(LibModel::Vendor, 4 * p0) / t(LibModel::Vendor, p0);
        let pccl_growth = t(LibModel::PcclRec, 4 * p0) / t(LibModel::PcclRec, p0);
        assert!(
            vendor_growth > 2.0 * pccl_growth,
            "vendor {vendor_growth:.2} vs pccl {pccl_growth:.2} at p0={p0}"
        );
    });
}

#[test]
fn paper_headline_speedups_hold_in_band() {
    // The abstract's numbers, as order-of-magnitude bands on 2048 GCDs of
    // Frontier vs RCCL: 168× RS (we demand >25), 33× AG (>10), 10× AR (>2)
    // and the corresponding Perlmutter gains stay modest (<20×).
    let speedup = |machine, kind, msg| {
        let v = simulate(machine, LibModel::Vendor, kind, msg, 2048, 10, 5)
            .unwrap()
            .stats
            .mean();
        let p = simulate(machine, LibModel::PcclRec, kind, msg, 2048, 10, 5)
            .unwrap()
            .stats
            .mean();
        v / p
    };
    let ag = speedup(Machine::Frontier, CollKind::AllGather, 32 * MB);
    let rs = speedup(Machine::Frontier, CollKind::ReduceScatter, 16 * MB);
    let ar = speedup(Machine::Frontier, CollKind::AllReduce, 16 * MB);
    assert!(ag > 10.0, "Frontier AG speedup {ag:.1}");
    assert!(rs > 25.0 && rs > ag, "Frontier RS speedup {rs:.1}");
    assert!(ar > 2.0, "Frontier AR speedup {ar:.1}");

    let ag_p = speedup(Machine::Perlmutter, CollKind::AllGather, 32 * MB);
    assert!(
        ag_p > 1.5 && ag_p < 20.0,
        "Perlmutter AG speedup {ag_p:.1} should be modest"
    );
    let ar_p = speedup(Machine::Perlmutter, CollKind::AllReduce, 64 * MB);
    assert!(
        ar_p > 0.4 && ar_p < 3.0,
        "Perlmutter AR ≈ parity, got {ar_p:.1}"
    );
}

#[test]
fn bandwidth_bound_regime_vendor_wins() {
    // Top-left of the heatmaps: large msg, few ranks — vendor ring ≥ PCCL.
    let v = simulate(Machine::Frontier, LibModel::Vendor, CollKind::AllGather, 1024 * MB, 32, 1, 1)
        .unwrap()
        .stats
        .mean();
    let p = simulate(Machine::Frontier, LibModel::PcclRec, CollKind::AllGather, 1024 * MB, 32, 1, 1)
        .unwrap()
        .stats
        .mean();
    assert!(v < p, "vendor {v} should beat pccl {p} bandwidth-bound");
}

#[test]
fn dataset_labels_are_argmin_by_construction() {
    let d = Dataset::build(
        Machine::Frontier,
        CollKind::ReduceScatter,
        &[4, 64, 1024],
        &[32, 256, 2048],
        3,
        9,
    )
    .unwrap();
    for s in &d.samples {
        let labeled = Backend::CONCRETE[s.label];
        let labeled_lib = LibModel::from_backend(labeled).unwrap();
        let t_label = simulate(
            Machine::Frontier,
            labeled_lib,
            CollKind::ReduceScatter,
            s.msg,
            s.ranks,
            3,
            9,
        )
        .unwrap()
        .stats
        .mean();
        for b in Backend::CONCRETE {
            let lib = LibModel::from_backend(b).unwrap();
            let t = simulate(Machine::Frontier, lib, CollKind::ReduceScatter, s.msg, s.ranks, 3, 9)
                .unwrap()
                .stats
                .mean();
            assert!(
                t_label <= t * 1.0000001,
                "label {labeled:?} not argmin at msg={} p={}",
                s.msg,
                s.ranks
            );
        }
    }
}

#[test]
fn dispatcher_beats_every_fixed_backend_overall() {
    // The adaptive dispatcher's whole point: over a grid spanning both
    // regimes, total time with dispatch ≤ total time of the best single
    // backend.
    let sizes = [16usize, 64, 256, 1024];
    let ranks = [32usize, 128, 512, 2048];
    let d = SvmDispatcher::train(Machine::Frontier, &sizes, &ranks, 3, 21).unwrap();
    let mut fixed_totals = vec![0.0f64; Backend::CONCRETE.len()];
    let mut auto_total = 0.0;
    for &mb in &sizes {
        for &p in &ranks {
            for (i, b) in Backend::CONCRETE.iter().enumerate() {
                let lib = LibModel::from_backend(*b).unwrap();
                fixed_totals[i] +=
                    simulate(Machine::Frontier, lib, CollKind::AllGather, mb * MB, p, 3, 2)
                        .unwrap()
                        .stats
                        .mean();
            }
            let chosen = d.choose(CollKind::AllGather, mb * MB, p);
            let lib = LibModel::from_backend(chosen).unwrap();
            auto_total += simulate(Machine::Frontier, lib, CollKind::AllGather, mb * MB, p, 3, 2)
                .unwrap()
                .stats
                .mean();
        }
    }
    let best_fixed = fixed_totals.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        auto_total <= best_fixed * 1.02,
        "auto {auto_total} vs best fixed {best_fixed}"
    );
}
