//! End-to-end tests of the op-level trace pipeline: builder → verifier →
//! engine → tracer. A traced trial's spans must agree op-for-op with the
//! verified plan's [`phase_shapes`] cost model, the chrome://tracing
//! export must follow the Trace Event Format, and tracing must never
//! leak into the timed loop.

use pccl::backends::{plan_spec_for, Backend, CollKind};
use pccl::collectives::plan::phase_shapes;
use pccl::error::Error;
use pccl::runtime::{Launcher, LauncherConfig, PersistentWorld, TrialReport};
use pccl::topology::Topology;
use pccl::trace;
use pccl::util::json::Value;

fn tiny_launcher(topo: Topology) -> Launcher {
    Launcher::new(LauncherConfig {
        topologies: vec![topo],
        elem_counts: vec![1 << 12],
        trials: 1,
        inner_iters: 1,
        warmup_iters: 0,
        persistent: false,
        lane_counts: vec![1],
    })
}

/// The §III-A shape convention inverted: recover the per-rank input
/// element count `cell_shape` fed the collective from the cell's
/// recorded message bytes.
fn input_len_of(cell: &pccl::runtime::MeasuredCell) -> usize {
    match cell.kind {
        CollKind::AllGather => cell.msg_bytes / 4 / cell.ranks,
        CollKind::ReduceScatter | CollKind::AllReduce => cell.msg_bytes / 4,
    }
}

#[test]
fn chrome_trace_export_matches_golden_schema() {
    let topo = Topology::flat(4);
    let cell = tiny_launcher(topo)
        .time_cell(topo, CollKind::AllReduce, Backend::PcclRing, 1 << 12)
        .unwrap();
    let tr = cell.trace.as_ref().expect("concrete backend cell is traced");
    let span_count: usize = tr.per_rank.iter().map(Vec::len).sum();
    assert!(span_count > 0, "traced run recorded no spans");

    let doc = trace::chrome_trace_doc(&[("all-reduce/pccl_ring".to_string(), tr)]);
    let parsed = Value::parse(&doc.to_string()).expect("export must be valid JSON");
    assert_eq!(parsed.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");

    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    // One process-name metadata record, then one complete event per span.
    assert_eq!(events[0].get("ph").unwrap().as_str().unwrap(), "M");
    assert_eq!(events[0].get("name").unwrap().as_str().unwrap(), "process_name");
    assert_eq!(events.len(), 1 + span_count);
    for ev in &events[1..] {
        assert_eq!(ev.get("ph").unwrap().as_str().unwrap(), "X");
        assert!(ev.get("ts").unwrap().as_f64().unwrap() >= 0.0);
        assert!(ev.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        let _ = ev.get("pid").unwrap().as_usize().unwrap();
        let tid = ev.get("tid").unwrap().as_usize().unwrap();
        assert!(tid < topo.world_size());
        let cat = ev.get("cat").unwrap().as_str().unwrap();
        assert!(matches!(cat, "world" | "inter" | "intra"), "bad scope {cat}");
        let args = ev.get("args").unwrap();
        for key in ["phase", "round", "lanes", "sent_bytes", "recvd_bytes", "combine_bytes"] {
            let _ = args.get(key).unwrap().as_usize().unwrap();
        }
    }
}

#[test]
fn traced_phase_counts_match_phase_shapes() {
    let cases = [
        (Topology::flat(3), Backend::Vendor),
        (Topology::flat(6), Backend::CrayMpich),
        (Topology::flat(8), Backend::PcclRec),
        (Topology::new(2, 3, 1).unwrap(), Backend::PcclRing),
        (Topology::new(2, 4, 1).unwrap(), Backend::PcclRec),
    ];
    for (topo, backend) in cases {
        for kind in CollKind::ALL {
            let cell = tiny_launcher(topo)
                .time_cell(topo, kind, backend, 1 << 12)
                .unwrap_or_else(|e| {
                    panic!("{}/{} on {topo:?}: {e}", kind.label(), backend.label())
                });
            let tr = cell.trace.as_ref().expect("traced trial attached");
            let input_len = input_len_of(&cell);
            let spec = plan_spec_for(kind, backend, topo, input_len, 1);

            // The launcher already ran this guard before returning the
            // cell; re-run it explicitly so a regression in the wiring
            // (guard silently skipped) also fails here.
            trace::check_phases(tr, &spec, 4).unwrap_or_else(|e| {
                panic!("{}/{} on {topo:?}: {e}", kind.label(), backend.label())
            });

            let shapes = phase_shapes(&spec).unwrap();
            assert!(tr.phases.len() <= shapes.len());
            for (i, ph) in tr.phases.iter().enumerate() {
                let want_sent: u64 =
                    shapes[i].rounds.iter().map(|r| r.sent_elems).sum::<u64>() * 4;
                let want_combine: u64 =
                    shapes[i].rounds.iter().map(|r| r.combine_elems).sum::<u64>() * 4;
                assert_eq!(
                    (ph.sent_bytes, ph.combine_bytes),
                    (want_sent, want_combine),
                    "{}/{} on {topo:?} phase {i}",
                    kind.label(),
                    backend.label()
                );
                assert!(ph.rounds as usize <= shapes[i].rounds.len());
                assert!(ph.ops > 0, "observed phase with no ops");
            }
            // Any plan phase the trace never reached must schedule nothing.
            for shape in &shapes[tr.phases.len()..] {
                let volume: u64 =
                    shape.rounds.iter().map(|r| r.sent_elems + r.combine_elems).sum();
                assert_eq!(volume, 0, "unreached plan phase schedules volume");
            }
            // The netsim prediction covers every observed phase.
            assert!(cell.predicted_phase_s.len() >= tr.phases.len());
            assert!(cell.predicted_phase_s.iter().all(|s| s.is_finite() && *s > 0.0));
        }
    }
}

#[test]
fn forged_trace_is_rejected_by_the_phase_guard() {
    let topo = Topology::flat(4);
    let cell = tiny_launcher(topo)
        .time_cell(topo, CollKind::AllGather, Backend::PcclRing, 1 << 12)
        .unwrap();
    let mut tr = cell.trace.clone().expect("traced trial attached");
    let spec = plan_spec_for(CollKind::AllGather, Backend::PcclRing, topo, input_len_of(&cell), 1);
    trace::check_phases(&tr, &spec, 4).unwrap();

    // One extra byte on rank 0's first span must break the byte-exact
    // comparison against the verified plan.
    tr.per_rank[0][0].sent_bytes += 4;
    let err = trace::check_phases(&tr, &spec, 4).unwrap_err();
    assert!(
        err.to_string().contains("verified plan schedules"),
        "unexpected error: {err}"
    );
}

#[test]
fn tracing_stays_out_of_the_timed_loop() {
    let mut world = PersistentWorld::<f32>::new(Topology::flat(2)).unwrap();
    let launcher = Launcher::new(LauncherConfig {
        topologies: vec![Topology::flat(2)],
        elem_counts: vec![1 << 10],
        trials: 2,
        inner_iters: 1,
        warmup_iters: 0,
        persistent: true,
        lane_counts: vec![1],
    });
    let cell = launcher
        .time_cell_in(&mut world, CollKind::AllReduce, Backend::PcclRing, 1 << 10)
        .unwrap();

    // The dedicated traced trial ran and saw exactly one collective op's
    // worth of traffic — the same schedule bytes the timed trials moved.
    let tr = cell.trace.as_ref().expect("traced trial attached");
    let traced_sent: u64 = tr.phases.iter().map(|p| p.total_sent_bytes).sum();
    assert_eq!(traced_sent, cell.bytes_per_op);
    // Every timed trial contributed a sample (the traced one is extra).
    assert_eq!(cell.stats.count(), 2);

    // After the cell, the pinned rank threads carry no tracer: a trial
    // that would error under an installed tracer runs clean.
    let reports = world
        .run_trial(|_c| {
            if pccl::trace::is_active() {
                Err(Error::Dispatch("tracer leaked into a later trial".into()))
            } else {
                Ok(TrialReport::default())
            }
        })
        .unwrap();
    assert_eq!(reports.len(), 2);
}
