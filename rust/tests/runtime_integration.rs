//! Integration over the AOT artifacts (requires `make artifacts`; every
//! test skips with a notice when the artifacts are absent so plain
//! `cargo test` stays green in a fresh checkout).

use pccl::reduction::offload::XlaReducer;
use pccl::reduction::reduce_into;
use pccl::runtime::{Artifacts, DeviceService, HostTensor};

fn artifacts_or_skip() -> Option<Artifacts> {
    match Artifacts::load_default() {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("skipping: run `make artifacts` first");
            None
        }
    }
}

#[test]
fn manifest_names_all_resolve() {
    let Some(arts) = artifacts_or_skip() else { return };
    for name in arts.names() {
        assert!(arts.hlo_path(name).is_ok(), "{name} missing on disk");
    }
    let meta = arts.model().expect("model metadata");
    assert_eq!(meta.param_names.len(), meta.param_shapes.len());
    let count: usize = meta
        .param_shapes
        .iter()
        .map(|s| s.iter().product::<usize>())
        .sum();
    assert_eq!(count, meta.param_count);
}

#[test]
fn xla_reduce_matches_native() {
    let Some(arts) = artifacts_or_skip() else { return };
    let service = DeviceService::spawn(arts.clone()).unwrap();
    let reducer = XlaReducer::from_artifacts(&arts, service.handle(), 0)
        .unwrap()
        .expect("reduce_sum artifact");
    let n = reducer.chunk() + 1000; // exercise device chunks + host tail
    let mut acc_xla: Vec<f32> = (0..n).map(|i| (i % 97) as f32 * 0.5).collect();
    let src: Vec<f32> = (0..n).map(|i| (i % 31) as f32 * 0.25).collect();
    let mut acc_native = acc_xla.clone();
    reducer.reduce_into(&mut acc_xla, &src).unwrap();
    reduce_into(&mut acc_native, &src);
    assert_eq!(acc_xla, acc_native);
}

#[test]
fn unshuffle_artifact_matches_native_transpose() {
    let Some(arts) = artifacts_or_skip() else { return };
    let name = arts
        .names()
        .find(|n| n.starts_with("unshuffle_"))
        .expect("unshuffle artifact")
        .to_string();
    // Parse NxMxB from the name.
    let dims: Vec<usize> = name
        .trim_start_matches("unshuffle_")
        .split('x')
        .map(|s| s.parse().unwrap())
        .collect();
    let (n_nodes, m_local, block) = (dims[0], dims[1], dims[2]);
    let total = n_nodes * m_local * block;
    let service = DeviceService::spawn(arts).unwrap();
    let buf: Vec<f32> = (0..total).map(|i| i as f32).collect();
    let out = service
        .handle()
        .execute("unshuffle_4x2x1024", vec![HostTensor::f32(buf.clone(), vec![total])])
        .unwrap();
    let got = out.into_iter().next().unwrap().into_f32().unwrap();
    let want = pccl::collectives::unshuffle(&buf, n_nodes, m_local, block);
    assert_eq!(got, want, "L1 kernel ≠ L3 native shuffle");
}

#[test]
fn init_params_deterministic_across_calls() {
    let Some(arts) = artifacts_or_skip() else { return };
    let meta = arts.model().unwrap().clone();
    let service = DeviceService::spawn(arts).unwrap();
    let h = service.handle();
    let seed = HostTensor::i32(vec![7], vec![]);
    let a = h.execute("init_params", vec![seed.clone()]).unwrap();
    let b = h.execute("init_params", vec![seed]).unwrap();
    let c = h
        .execute("init_params", vec![HostTensor::i32(vec![8], vec![])])
        .unwrap();
    assert_eq!(a.len(), meta.param_shapes.len());
    assert_eq!(a, b, "same seed must give identical params");
    assert_ne!(a, c, "different seed must differ");
}

#[test]
fn train_step_single_rank_learns() {
    let Some(arts) = artifacts_or_skip() else { return };
    let meta = arts.model().unwrap().clone();
    let service = DeviceService::spawn(arts).unwrap();
    let h = service.handle();
    let mut params = pccl::train::params::ParamSet::init(&h, &meta, 3).unwrap();
    let mut first = None;
    let mut last = 0.0f32;
    let mut opt = pccl::train::optimizer::Sgd::new(0.5, 0.0);
    for step in 0..8 {
        let tokens = pccl::train::data::batch_tokens(
            1,
            0,
            step,
            meta.batch_per_rank,
            meta.seq_len,
            meta.vocab_size,
        );
        let mut inputs = params.tensors.clone();
        inputs.push(HostTensor::i32(
            tokens,
            vec![meta.batch_per_rank, meta.seq_len + 1],
        ));
        let mut out = h.execute("train_step", inputs).unwrap();
        let loss = out.remove(0).into_f32().unwrap()[0];
        first.get_or_insert(loss);
        last = loss;
        let grads = params.flatten_grads(&out).unwrap();
        let mut flat = params.flatten().unwrap();
        opt.step(&mut flat, &grads);
        params.load_flat(&flat).unwrap();
    }
    let first = first.unwrap();
    assert!(
        last < first,
        "loss should decrease within 8 steps: {first} → {last}"
    );
    // Fresh init predicts ~uniform: loss ≈ ln(vocab).
    let expect = (meta.vocab_size as f32).ln();
    assert!((first - expect).abs() < 1.0, "init loss {first} vs ln(V)={expect}");
}

#[test]
fn train_step_input_validation() {
    let Some(arts) = artifacts_or_skip() else { return };
    let service = DeviceService::spawn(arts).unwrap();
    // Wrong arity.
    let err = service
        .handle()
        .execute("train_step", vec![HostTensor::i32(vec![0], vec![1])])
        .unwrap_err();
    assert!(err.to_string().contains("inputs"), "{err}");
}
