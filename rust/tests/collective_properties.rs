//! Property tests: every backend × every collective ≡ the naive oracle for
//! randomized element counts, rank counts, topologies, and dtypes — the
//! core correctness invariant of the library.

use pccl::backends::{all_gather, all_reduce, reduce_scatter, Backend, CollectiveOptions};
use pccl::collectives::oracle;
use pccl::comm::CommWorld;
use pccl::topology::Topology;
use pccl::util::bf16::Bf16;
use pccl::util::prop::{check, vec_f32};
use pccl::util::rng::Rng;

fn random_topology(rng: &mut Rng) -> Topology {
    // Mix of flat, hierarchical, non-power-of-two shapes.
    match rng.range_usize(0, 4) {
        0 => Topology::flat(rng.range_usize(1, 10)),
        1 => Topology::new(rng.range_usize(2, 5), rng.range_usize(2, 5), 1).unwrap(),
        2 => Topology::new(rng.range_usize(2, 4), 4, rng.range_usize(1, 3) * 2).unwrap(),
        _ => Topology::new(3, rng.range_usize(2, 4), 1).unwrap(), // non-pow2 nodes
    }
}

fn per_rank_inputs(rng: &mut Rng, p: usize, len: usize) -> Vec<Vec<f32>> {
    (0..p).map(|_| vec_f32(rng, len, 100.0)).collect()
}

#[test]
fn prop_all_gather_matches_oracle_every_backend() {
    check("all_gather ≡ oracle", 24, 0xA6, |rng| {
        let topo = random_topology(rng);
        let p = topo.world_size();
        let m = rng.range_usize(1, 40);
        let inputs = per_rank_inputs(rng, p, m);
        let expect = oracle::all_gather(&inputs);
        let backend = Backend::CONCRETE[rng.range_usize(0, 4)];
        let world = CommWorld::<f32>::with_topology(topo);
        let ins = inputs.clone();
        let outs = world.run(move |c| {
            let opts = CollectiveOptions::default().backend(backend);
            all_gather(c, &ins[c.rank()], &opts).unwrap()
        });
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o, &expect, "backend={backend:?} rank={r} p={p} m={m}");
        }
    });
}

#[test]
fn prop_reduce_scatter_matches_oracle_every_backend() {
    check("reduce_scatter ≡ oracle", 24, 0x125, |rng| {
        let topo = random_topology(rng);
        let p = topo.world_size();
        let b = rng.range_usize(1, 20);
        let inputs = per_rank_inputs(rng, p, p * b);
        let backend = Backend::CONCRETE[rng.range_usize(0, 4)];
        let world = CommWorld::<f32>::with_topology(topo);
        let ins = inputs.clone();
        let outs = world.run(move |c| {
            let opts = CollectiveOptions::default().backend(backend);
            reduce_scatter(c, &ins[c.rank()], &opts).unwrap()
        });
        for (r, o) in outs.iter().enumerate() {
            let expect = oracle::reduce_scatter(&inputs, r);
            for (got, want) in o.iter().zip(&expect) {
                assert!(
                    (got - want).abs() <= want.abs() * 1e-5 + 1e-4,
                    "backend={backend:?} rank={r}: {got} vs {want}"
                );
            }
        }
    });
}

#[test]
fn prop_all_reduce_matches_oracle_every_backend() {
    check("all_reduce ≡ oracle", 24, 0xAA, |rng| {
        let topo = random_topology(rng);
        let p = topo.world_size();
        let n = rng.range_usize(1, 70); // deliberately often unaligned to p
        let inputs = per_rank_inputs(rng, p, n);
        let expect = oracle::all_reduce(&inputs);
        let backend = Backend::CONCRETE[rng.range_usize(0, 4)];
        let world = CommWorld::<f32>::with_topology(topo);
        let ins = inputs.clone();
        let outs = world.run(move |c| {
            let opts = CollectiveOptions::default().backend(backend);
            all_reduce(c, &ins[c.rank()], &opts).unwrap()
        });
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o.len(), n);
            for (got, want) in o.iter().zip(&expect) {
                assert!(
                    (got - want).abs() <= want.abs() * 1e-5 + 1e-4,
                    "backend={backend:?} rank={r}: {got} vs {want}"
                );
            }
        }
    });
}

#[test]
fn prop_backends_agree_with_each_other() {
    // Hierarchical ≡ flat: all backends produce identical all-gather bytes
    // and near-identical reductions on the same inputs.
    check("backends agree", 12, 0xB0, |rng| {
        let topo = Topology::new(2, rng.range_usize(2, 5), 1).unwrap();
        let p = topo.world_size();
        let n = p * rng.range_usize(1, 8);
        let inputs = per_rank_inputs(rng, p, n);
        let mut results: Vec<Vec<Vec<f32>>> = Vec::new();
        for backend in Backend::CONCRETE {
            let world = CommWorld::<f32>::with_topology(topo);
            let ins = inputs.clone();
            results.push(world.run(move |c| {
                let opts = CollectiveOptions::default().backend(backend);
                reduce_scatter(c, &ins[c.rank()], &opts).unwrap()
            }));
        }
        for other in &results[1..] {
            for (r, (a, b)) in results[0].iter().zip(other).enumerate() {
                for (x, y) in a.iter().zip(b) {
                    assert!((x - y).abs() <= x.abs() * 1e-5 + 1e-4, "rank {r}");
                }
            }
        }
    });
}

#[test]
fn generic_dtypes_f64_and_bf16() {
    // f64 exact, bf16 within truncation error.
    let topo = Topology::new(2, 2, 1).unwrap();
    let world = CommWorld::<f64>::with_topology(topo);
    let outs = world.run(|c| {
        let input: Vec<f64> = (0..8).map(|i| (c.rank() * 10 + i) as f64 * 0.5).collect();
        let opts = CollectiveOptions::default().backend(Backend::PcclRec);
        all_reduce(c, &input, &opts).unwrap()
    });
    let ins: Vec<Vec<f64>> = (0..4)
        .map(|r| (0..8).map(|i| (r * 10 + i) as f64 * 0.5).collect())
        .collect();
    assert_eq!(outs[0], oracle::all_reduce(&ins));

    let world = CommWorld::<Bf16>::with_topology(topo);
    let outs = world.run(|c| {
        let input: Vec<Bf16> = (0..4).map(|i| Bf16::from_f32((c.rank() + i) as f32)).collect();
        let opts = CollectiveOptions::default().backend(Backend::PcclRing);
        all_gather(c, &input, &opts).unwrap()
    });
    assert_eq!(outs[0].len(), 16);
    assert_eq!(outs[0][5].to_f32(), 2.0); // rank 1, i=1
}

#[test]
fn repeated_collectives_interleave_safely() {
    // Many back-to-back ops on the same communicator (tag freshness) plus
    // alternating backends.
    let topo = Topology::new(2, 4, 2).unwrap();
    let world = CommWorld::<f32>::with_topology(topo);
    let outs = world.run(|c| {
        let mut acc = 0.0f32;
        for round in 0..12 {
            let backend = Backend::CONCRETE[round % 4];
            let opts = CollectiveOptions::default().backend(backend);
            let input = vec![(c.rank() + round) as f32; 16];
            let out = all_reduce(c, &input, &opts).unwrap();
            acc += out[0];
        }
        acc
    });
    // Round r: sum over ranks of (rank + r) = 28 + 8r; total over rounds.
    let expect: f32 = (0..12).map(|r| 28.0 + 8.0 * r as f32).sum();
    for o in outs {
        assert_eq!(o, expect);
    }
}

#[test]
fn large_buffer_smoke() {
    // 4 MiB per rank through the hierarchical path.
    let topo = Topology::new(2, 4, 2).unwrap();
    let world = CommWorld::<f32>::with_topology(topo);
    let n = 1 << 20;
    let outs = world.run(move |c| {
        let input = vec![1.0f32; n];
        let opts = CollectiveOptions::default().backend(Backend::PcclRec);
        all_reduce(c, &input, &opts).unwrap()
    });
    assert!(outs.iter().all(|o| o.len() == n && o[0] == 8.0 && o[n - 1] == 8.0));
}

#[test]
fn max_min_ops_through_public_api() {
    use pccl::reduction::ReduceOp;
    let topo = Topology::new(2, 2, 1).unwrap();
    let world = CommWorld::<f32>::with_topology(topo);
    let outs = world.run(|c| {
        let input = vec![c.rank() as f32, -(c.rank() as f32)];
        let max = all_reduce(
            c,
            &input,
            &CollectiveOptions::default().op(ReduceOp::Max),
        )
        .unwrap();
        let min = all_reduce(
            c,
            &input,
            &CollectiveOptions::default()
                .backend(Backend::Vendor)
                .op(ReduceOp::Min),
        )
        .unwrap();
        (max, min)
    });
    for (max, min) in outs {
        assert_eq!(max, vec![3.0, 0.0]);
        assert_eq!(min, vec![0.0, -3.0]);
    }
}

#[test]
fn rooted_collectives_compose_with_training_pattern() {
    // ZeRO-init pattern: root broadcasts params, ranks compute, reduce to
    // root, root scatters — a realistic composition over one communicator.
    use pccl::backends::{broadcast, gather, reduce, scatter};
    let topo = Topology::new(2, 3, 1).unwrap();
    let world = CommWorld::<f32>::with_topology(topo);
    let outs = world.run(move |c| {
        let params = broadcast(c, &vec![1.5f32; 6], 0).unwrap();
        let local: Vec<f32> = params.iter().map(|v| v * (c.rank() + 1) as f32).collect();
        let opts = CollectiveOptions::default();
        let summed = reduce(c, &local, 0, &opts).unwrap();
        let shard = if c.rank() == 0 {
            scatter(c, &summed, 0).unwrap()
        } else {
            scatter(c, &[], 0).unwrap()
        };
        let all = gather(c, &shard, 0).unwrap();
        (shard, all)
    });
    // sum over ranks of 1.5·(r+1) = 1.5·21 = 31.5 elementwise.
    let total = 1.5 * (1..=6).sum::<usize>() as f32;
    for (r, (shard, all)) in outs.iter().enumerate() {
        assert_eq!(shard, &vec![total; 1], "rank {r}");
        if r == 0 {
            assert_eq!(all, &vec![total; 6]);
        }
    }
}

#[test]
fn prop_pipelined_all_gather_matches_plain() {
    use pccl::collectives::{hier_all_gather, pipelined_hier_all_gather, InterAlgo};
    check("pipelined ≡ plain", 12, 0xD1, |rng| {
        let topo = Topology::new(rng.range_usize(2, 5), rng.range_usize(2, 4), 1).unwrap();
        let chunks = 1 << rng.range_usize(0, 3);
        let m = chunks * rng.range_usize(1, 6);
        let world = CommWorld::<f32>::with_topology(topo);
        let outs = world.run(move |c| {
            let input: Vec<f32> = (0..m).map(|i| (c.rank() * 31 + i) as f32).collect();
            let plain = hier_all_gather(c, &input, InterAlgo::Rec).unwrap();
            let piped =
                pipelined_hier_all_gather(c, &input, InterAlgo::Rec, chunks).unwrap();
            (plain, piped)
        });
        for (plain, piped) in outs {
            assert_eq!(plain, piped);
        }
    });
}

#[test]
fn stress_many_small_ops_many_ranks() {
    // 12 ranks × 60 small collectives: exercises tag namespacing, mailbox
    // stashing, and sub-communicator reuse under pressure.
    let topo = Topology::new(3, 4, 2).unwrap();
    let world = CommWorld::<f32>::with_topology(topo);
    let outs = world.run(|c| {
        let mut checksum = 0.0f64;
        for i in 0..60 {
            let opts =
                CollectiveOptions::default().backend(Backend::CONCRETE[i % 4]);
            let v = all_reduce(c, &[1.0, c.rank() as f32], &opts).unwrap();
            checksum += (v[0] + v[1]) as f64;
        }
        checksum
    });
    // Each op: sum of ones = 12; sum of ranks = 66 → 78 per op.
    for o in outs {
        assert_eq!(o, 60.0 * 78.0);
    }
}
