//! ZeRO-3 sharded training end to end: parameters sharded across ranks,
//! all-gathered (PCCL all-gather) before each step, gradients
//! reduce-scattered (PCCL reduce-scatter) — the Fig. 12 workload on the
//! real data plane.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example zero3_train -- [steps] [ranks]
//! ```

use pccl::backends::Backend;
use pccl::train::{zero3::run_zero3, Zero3Config};

fn main() -> pccl::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(100);
    let ranks: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let cfg = Zero3Config {
        ranks,
        steps,
        lr: 0.5,
        momentum: 0.9,
        backend: Backend::PcclRec,
        ..Default::default()
    };
    println!(
        "ZeRO-3 training: {} rank threads, {} steps, backend={}",
        cfg.ranks,
        cfg.steps,
        cfg.backend.label()
    );
    let report = run_zero3(&cfg)?;
    println!(
        "params: {} total, {} elems/shard/rank",
        report.param_count, report.shard_elems
    );
    for (i, loss) in report.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == report.losses.len() {
            println!("step {i:>4}  loss {loss:.4}");
        }
    }
    assert!(
        report.final_loss() < report.losses[0] * 0.8,
        "training must reduce the loss"
    );
    println!("zero3_train OK");
    Ok(())
}
