//! Adaptive dispatching, end to end: train the SVM dispatcher on the
//! Frontier netsim sweep, show that different (collective, size, ranks)
//! points route to different backends through the trained model, persist
//! the model via the artifact registry, run a *measured* sweep of the real
//! data plane with the multi-rank launcher and train a second dispatcher
//! on those timings, and finally route real collectives through
//! `Backend::Auto` via the `Pccl` facade.
//!
//! ```bash
//! cargo run --release --example dispatch_demo
//! ```

use std::sync::Arc;

use pccl::backends::CollKind;
use pccl::collectives::Pccl;
use pccl::dispatch::SvmDispatcher;
use pccl::runtime::{Artifacts, Launcher, LauncherConfig};
use pccl::topology::{Machine, Topology};

fn print_decision_map(d: &SvmDispatcher, sizes_mb: &[usize], ranks: &[usize]) {
    print!("{:>8}", "");
    for p in ranks {
        print!("{p:>12}");
    }
    println!();
    for &mb in sizes_mb {
        print!("{mb:>6}MB");
        for &p in ranks {
            let b = d.choose(CollKind::AllGather, mb << 20, p);
            print!(" {:>11}", b.label());
        }
        println!();
    }
}

fn main() -> pccl::Result<()> {
    // 1. Train on the Frontier netsim sweep (the paper's protocol:
    //    message-size × rank-count grid, argmin-labeled, 5-fold CV).
    println!("training SVM dispatcher on the Frontier netsim sweep...");
    let dispatcher = Arc::new(SvmDispatcher::train(
        Machine::Frontier,
        &[16, 32, 64, 128, 256, 512, 1024],
        &[32, 64, 128, 256, 512, 1024, 2048],
        5,
        42,
    )?);

    println!("\nall-gather decision map (rows = msg MiB, cols = ranks):");
    print_decision_map(&dispatcher, &[16, 64, 256, 1024], &[32, 128, 512, 2048]);

    println!("\ndispatcher test accuracy (Table I rows):");
    for (coll, size, correct, acc) in dispatcher.table1() {
        println!("  {coll:<16} {correct}/{size} = {acc:.1}%");
    }

    // The headline property: the trained SVM sends different (collective,
    // size, ranks) points to different backends.
    let bw = dispatcher.choose(CollKind::AllGather, 1024 << 20, 32);
    let lat = dispatcher.choose(CollKind::AllGather, 16 << 20, 2048);
    assert_ne!(bw, lat, "trained dispatcher must split the regimes");
    println!("\nbandwidth-bound (1 GiB × 32 ranks)   → {}", bw.label());
    println!("latency-bound   (16 MiB × 2048 ranks) → {}", lat.label());

    // 2. Persist via the artifact registry; reload and verify routing.
    let arts = Artifacts::open_or_init(Artifacts::default_dir())?;
    let path = arts.save_dispatcher(&dispatcher)?;
    let reloaded = arts.load_dispatcher(Machine::Frontier)?;
    assert_eq!(reloaded.choose(CollKind::AllGather, 16 << 20, 2048), lat);
    println!("\npersisted dispatcher artifact → {}", path.display());

    // 3. Measured sweep of the real data plane in persistent-world mode:
    //    pinned rank threads serve every trial from a work queue (world
    //    setup amortized, warmup before each timed section), and a second
    //    dispatcher trains on those measurements.
    println!("\nmeasuring the real data plane (persistent world, pinned rank threads)...");
    let launcher = Launcher::new(LauncherConfig {
        topologies: vec![
            Topology::flat(2),
            Topology::new(2, 2, 1)?,
            Topology::new(2, 4, 2)?,
        ],
        elem_counts: vec![1 << 10, 1 << 14, 1 << 17],
        trials: 3,
        inner_iters: 4,
        warmup_iters: 1,
        persistent: true,
    });
    let sweep = launcher.sweep()?;
    println!(
        "  {} measured cells, {} moved per sweep pass",
        sweep.cells.len(),
        pccl::metrics::fmt_bytes(sweep.total_bytes_per_op())
    );
    let measured = sweep.train_dispatcher(Machine::Generic, 7)?;
    println!("  measured-data dispatcher accuracy:");
    for (coll, size, correct, acc) in measured.table1() {
        println!("    {coll:<16} {correct}/{size} = {acc:.1}%");
    }

    // 4. Route real collectives through Backend::Auto via the facade.
    let pccl_auto = Pccl::<f32>::with_dispatcher(Arc::clone(&dispatcher));
    let world = pccl::comm::CommWorld::<f32>::new(8);
    let facade = pccl_auto.clone();
    let outs = world.try_run(move |comm| {
        let ag = facade.all_gather(comm, &[comm.rank() as f32; 256])?;
        let ar = facade.all_reduce(comm, &[1.0f32; 64])?;
        Ok((ag.len(), ar[0]))
    })?;
    assert!(outs.iter().all(|&(n, s)| n == 8 * 256 && s == 8.0));
    println!("\nauto-dispatched all-gather + all-reduce over 8 ranks OK");
    Ok(())
}
