//! Adaptive dispatching demo: train the SVM dispatcher on netsim sweep
//! data for Frontier, print its decision map, and use it through the
//! `Backend::Auto` path of the public API.
//!
//! ```bash
//! cargo run --release --example dispatch_demo
//! ```

use std::sync::Arc;

use pccl::backends::{all_gather, Backend, CollKind, CollectiveOptions};
use pccl::comm::CommWorld;
use pccl::dispatch::SvmDispatcher;
use pccl::topology::Machine;

fn main() -> pccl::Result<()> {
    println!("training SVM dispatcher on Frontier sweep data...");
    let dispatcher = Arc::new(SvmDispatcher::train(
        Machine::Frontier,
        &[16, 32, 64, 128, 256, 512, 1024],
        &[32, 64, 128, 256, 512, 1024, 2048],
        5,
        42,
    )?);

    // Decision map over the paper's heatmap grid (Fig. 11 structure).
    println!("\nall-gather backend decision map (rows = msg MiB, cols = ranks):");
    print!("{:>8}", "");
    for p in [32, 128, 512, 2048] {
        print!("{p:>12}");
    }
    println!();
    for mb in [16usize, 64, 256, 1024] {
        print!("{mb:>6}MB");
        for p in [32usize, 128, 512, 2048] {
            let b = dispatcher.choose(CollKind::AllGather, mb << 20, p);
            print!(" {:>11}", b.label());
        }
        println!();
    }

    // Table I rows for this machine.
    println!("\ndispatcher test accuracy:");
    for (coll, size, correct, acc) in dispatcher.table1() {
        println!("  {coll:<16} {correct}/{size} = {acc:.1}%");
    }

    // Use it through the public API on the real data plane.
    let chooser = dispatcher.chooser();
    let world = CommWorld::<f32>::new(8);
    let outs = world.try_run(move |comm| {
        let opts = CollectiveOptions::default()
            .backend(Backend::Auto)
            .chooser(chooser.clone());
        all_gather(comm, &[comm.rank() as f32; 256], &opts)
    })?;
    assert_eq!(outs[0].len(), 8 * 256);
    println!("\nAuto-dispatched all-gather over 8 ranks OK");
    Ok(())
}
