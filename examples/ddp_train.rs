//! End-to-end DDP training — the headline example proving all three layers
//! compose: the L1 Pallas kernels and L2 JAX GPT lower into
//! `artifacts/train_step.hlo.txt` (build once with `make artifacts`), the
//! L3 Rust coordinator runs rank threads that execute the step via PJRT and
//! all-reduce gradients through PCCL's hierarchical collectives, and the
//! loss curve is logged (recorded in EXPERIMENTS.md).
//!
//! ```bash
//! make artifacts
//! cargo run --release --example ddp_train -- [steps] [ranks]
//! ```

use pccl::backends::Backend;
use pccl::topology::Topology;
use pccl::train::{ddp::run_ddp, DdpConfig};

fn main() -> pccl::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let ranks: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let cfg = DdpConfig {
        ranks,
        topology: Some(Topology::new(2, ranks / 2, 1)?),
        steps,
        lr: 0.5,
        momentum: 0.9,
        backend: Backend::PcclRec,
        // PyTorch-DDP-style bucketing (48-80 MB at real scale; scaled to
        // the laptop model here).
        bucket_kb: Some(128),
        artifacts: None,
        seed: 7,
    };
    println!(
        "DDP training: {} rank threads, {} steps, backend={}",
        cfg.ranks,
        cfg.steps,
        cfg.backend.label()
    );
    let report = run_ddp(&cfg)?;
    println!("model parameters: {}", report.param_count);
    for (i, loss) in report.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == report.losses.len() {
            println!("step {i:>4}  loss {loss:.4}");
        }
    }
    let mean_step = report.step_secs.iter().sum::<f64>() / report.step_secs.len().max(1) as f64;
    println!(
        "loss: {:.4} → {:.4} over {} steps ({:.1} ms/step)",
        report.initial_loss(),
        report.final_loss(),
        report.losses.len(),
        mean_step * 1e3
    );
    assert!(
        report.final_loss() < report.initial_loss() * 0.8,
        "training must reduce the loss"
    );
    println!("ddp_train OK");
    Ok(())
}
