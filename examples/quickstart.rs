//! Quickstart: an 8-rank hierarchical all-reduce in a dozen lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pccl::backends::{all_reduce, Backend, CollectiveOptions};
use pccl::comm::CommWorld;
use pccl::topology::Topology;

fn main() -> pccl::Result<()> {
    // 2 "nodes" × 4 "GPUs": the hierarchical algorithms kick in.
    let topo = Topology::new(2, 4, 2)?;
    let world = CommWorld::<f32>::with_topology(topo);

    let outs = world.try_run(|comm| {
        let mine = vec![(comm.rank() + 1) as f32; 8];
        let opts = CollectiveOptions::default().backend(Backend::PcclRec);
        all_reduce(comm, &mine, &opts)
    })?;

    // Sum over ranks of (rank+1) = 1+2+...+8 = 36, elementwise.
    for (rank, out) in outs.iter().enumerate() {
        assert!(out.iter().all(|&v| v == 36.0));
        println!("rank {rank}: all_reduce → {:?}", &out[..4]);
    }
    println!("quickstart OK");
    Ok(())
}
