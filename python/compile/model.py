"""L2: GPT-style decoder (Table-II architecture family, laptop-scaled) in
JAX, calling the L1 Pallas kernels for layernorm/GELU. Lowered once by
``aot.py``; never imported at runtime.

The model matches the paper's workloads structurally (token + learned
positional embeddings, pre-LN blocks, causal attention, 4× MLP, untied LM
head); the default configuration is scaled to what one CPU core can train
for a few hundred steps (the substitution table in DESIGN.md records this).
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import fused


@dataclass(frozen=True)
class ModelConfig:
    # Default scale is set by the runtime substrate: the published `xla`
    # crate pins xla_extension 0.5.1, whose CPU backend executes this model
    # ~35× slower than a current jaxlib (measured in EXPERIMENTS.md §Perf).
    # These defaults keep the end-to-end DDP example at ≈100 ms/step so a
    # few-hundred-step loss curve completes in minutes on one core.
    vocab: int = 256
    seq: int = 32
    d_model: int = 64
    layers: int = 2
    heads: int = 4
    batch_per_rank: int = 4

    @property
    def d_head(self) -> int:
        assert self.d_model % self.heads == 0
        return self.d_model // self.heads


# Parameter layout: a flat list of arrays with parallel names — explicit
# ordering is the AOT contract with the Rust side (manifest param_names).
def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    spec = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq, cfg.d_model)),
    ]
    for l in range(cfg.layers):
        d, h = cfg.d_model, 4 * cfg.d_model
        spec += [
            (f"l{l}.ln1_g", (d,)),
            (f"l{l}.ln1_b", (d,)),
            (f"l{l}.qkv", (d, 3 * d)),
            (f"l{l}.attn_o", (d, d)),
            (f"l{l}.ln2_g", (d,)),
            (f"l{l}.ln2_b", (d,)),
            (f"l{l}.mlp_up", (d, h)),
            (f"l{l}.mlp_down", (h, d)),
        ]
    spec += [
        ("ln_f_g", (cfg.d_model,)),
        ("ln_f_b", (cfg.d_model,)),
        ("head", (cfg.d_model, cfg.vocab)),
    ]
    return spec


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_spec(cfg))


def init_params(seed, cfg: ModelConfig) -> List[jax.Array]:
    """Deterministic initialization from an i32 seed scalar (AOT entry
    ``init_params`` — identical replicas on every rank, Python-free)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b",)):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            # GPT-2-style: small embeddings AND a small LM head, so a fresh
            # model predicts near-uniform (init loss ≈ ln vocab).
            if "emb" in name or name == "head":
                scale = 0.02
            else:
                scale = 1.0 / jnp.sqrt(fan_in)
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _attention(x, qkv_w, o_w, cfg: ModelConfig):
    """Multi-head causal self-attention over x[(tokens, d)] per batch row."""
    t, d = x.shape
    qkv = x @ qkv_w  # (t, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    def heads(a):
        return a.reshape(t, cfg.heads, cfg.d_head).transpose(1, 0, 2)
    q, k, v = heads(q), heads(k), heads(v)  # (h, t, dh)
    scores = q @ k.transpose(0, 2, 1) / jnp.sqrt(cfg.d_head).astype(x.dtype)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, :, :], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = (probs @ v).transpose(1, 0, 2).reshape(t, d)
    return out @ o_w


def forward(params: List[jax.Array], tokens, cfg: ModelConfig):
    """Logits for tokens[(batch, seq)] → (batch, seq, vocab)."""
    it = iter(params)
    tok_emb = next(it)
    pos_emb = next(it)
    b, t = tokens.shape

    x = tok_emb[tokens] + pos_emb[None, :t, :]

    def flat(z):
        return z.reshape(b * t, cfg.d_model)

    def unflat(z):
        return z.reshape(b, t, cfg.d_model)

    for _ in range(cfg.layers):
        ln1_g, ln1_b = next(it), next(it)
        qkv_w, o_w = next(it), next(it)
        ln2_g, ln2_b = next(it), next(it)
        up_w, down_w = next(it), next(it)
        h = unflat(fused.layernorm(flat(x), ln1_g, ln1_b))
        attn = jax.vmap(lambda row: _attention(row, qkv_w, o_w, cfg))(h)
        x = x + attn
        h2 = fused.layernorm(flat(x), ln2_g, ln2_b)
        mlp = fused.gelu(h2 @ up_w) @ down_w
        x = x + unflat(mlp)

    ln_f_g, ln_f_b = next(it), next(it)
    head = next(it)
    x = fused.layernorm(flat(x), ln_f_g, ln_f_b)
    return (x @ head).reshape(b, t, cfg.vocab)


def loss_fn(params, tokens_with_target, cfg: ModelConfig):
    """Mean next-token cross-entropy. ``tokens_with_target`` is
    ``(batch, seq+1)``: columns [0, seq) are inputs, [1, seq+1) targets."""
    inputs = tokens_with_target[:, :-1]
    targets = tokens_with_target[:, 1:]
    logits = forward(params, inputs, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return -jnp.mean(picked)


def train_step(params, tokens_with_target, cfg: ModelConfig):
    """AOT entry ``train_step``: returns (loss, *grads) — the gradient
    communication (all-reduce / reduce-scatter) happens in Rust."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens_with_target, cfg))(params)
    return (loss, *grads)
