"""AOT pipeline: lower the L1 kernels and the L2 model to HLO **text** and
write ``artifacts/manifest.json`` — the entire contract with the Rust side.

Run via ``make artifacts`` (idempotent: skipped when inputs are unchanged).
Python never runs again after this.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import reduce as kreduce
from .kernels import shuffle as kshuffle


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def lower_entry(out_dir, name, fn, example_args, inputs, outputs):
    """Lower ``fn`` at the example shapes, write HLO text, return the
    manifest entry."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  {name}: {len(text)} chars, {len(inputs)} in / {len(outputs)} out")
    return {"file": fname, "inputs": inputs, "outputs": outputs}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact directory")
    args = parser.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    entries = {}

    # ---- L1: reduction kernels (two sizes: fast-dispatch + large) -------
    for n in (4096, kreduce.BLOCK):
        block = min(n, kreduce.BLOCK)
        f32 = jax.ShapeDtypeStruct((n,), jnp.float32)
        entries[f"reduce_sum_{n}"] = lower_entry(
            out_dir,
            f"reduce_sum_{n}",
            lambda x, y, block=block: (kreduce.reduce_sum(x, y, block=block),),
            (f32, f32),
            [spec((n,)), spec((n,))],
            [spec((n,))],
        )

    # ---- L1: hierarchical unshuffle (example shape: 4 nodes × 2 local) --
    n_nodes, m_local, block = 4, 2, 1024
    total = n_nodes * m_local * block
    buf = jax.ShapeDtypeStruct((total,), jnp.float32)
    entries[f"unshuffle_{n_nodes}x{m_local}x{block}"] = lower_entry(
        out_dir,
        f"unshuffle_{n_nodes}x{m_local}x{block}",
        lambda b: (kshuffle.unshuffle(b, n_nodes, m_local, block),),
        (buf,),
        [spec((total,))],
        [spec((total,))],
    )

    # ---- L2: model init + train step ------------------------------------
    cfg = model.ModelConfig()
    pspec = model.param_spec(cfg)
    param_specs = [spec(s) for _, s in pspec]

    seed = jax.ShapeDtypeStruct((), jnp.int32)
    entries["init_params"] = lower_entry(
        out_dir,
        "init_params",
        lambda s: tuple(model.init_params(s, cfg)),
        (seed,),
        [spec((), "i32")],
        param_specs,
    )

    params_shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in pspec]
    tokens = jax.ShapeDtypeStruct((cfg.batch_per_rank, cfg.seq + 1), jnp.int32)
    entries["train_step"] = lower_entry(
        out_dir,
        "train_step",
        lambda *a: model.train_step(list(a[:-1]), a[-1], cfg),
        (*params_shapes, tokens),
        param_specs + [spec((cfg.batch_per_rank, cfg.seq + 1), "i32")],
        [spec(())] + param_specs,
    )

    manifest = {
        "version": 1,
        "entries": entries,
        "model": {
            "param_names": [n for n, _ in pspec],
            "param_shapes": [list(s) for _, s in pspec],
            "param_count": int(model.param_count(cfg)),
            "seq_len": cfg.seq,
            "batch_per_rank": cfg.batch_per_rank,
            "vocab_size": cfg.vocab,
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out_dir}/manifest.json ({len(entries)} entries, "
          f"{manifest['model']['param_count']} params)")


if __name__ == "__main__":
    main()
