"""Pure-jnp oracles for the L1 Pallas kernels — the correctness contract
checked by pytest/hypothesis at build time (kernel vs ref allclose)."""

import jax.numpy as jnp


def reduce_sum(x, y):
    return x + y


def reduce_sum_many(stacked):
    return jnp.sum(stacked, axis=0)


def unshuffle(buf, n_nodes: int, m_local: int, block: int):
    """(local, node, block) → (node, local, block), flat in/out."""
    return (
        buf.reshape(m_local, n_nodes, block)
        .transpose(1, 0, 2)
        .reshape(-1)
    )


def shuffle_gather(buf, n_nodes: int, m_local: int, block: int):
    """(node, local, block) → (local, node, block), flat in/out."""
    return (
        buf.reshape(n_nodes, m_local, block)
        .transpose(1, 0, 2)
        .reshape(-1)
    )


def layernorm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu(x):
    # tanh approximation (matches the kernel).
    c = jnp.sqrt(2.0 / jnp.pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))
