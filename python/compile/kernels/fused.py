"""L1 Pallas kernels used inside the L2 model: row-blocked layernorm and
GELU, with hand-written backward kernels wired through ``jax.custom_vjp``
(interpret-mode Pallas has no automatic reverse-mode, exactly like a CUDA
kernel library — forward and backward are both explicit kernels).

Tiling: one grid step holds a (ROWS, D) tile in VMEM — elementwise /
row-reduction VPU work, no MXU. With ``interpret=True`` they lower to plain
HLO and fuse into the surrounding XLA graph, so the AOT model artifact
carries the kernels' semantics with zero interpret-mode runtime cost.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM tile. d_model ≤ 1024 ⇒ tile ≤ 32×1024×4 B = 128 KiB.
ROWS = 32
EPS = 1e-5


def _row_tile(rows: int) -> int:
    tile = ROWS
    while rows % tile != 0:
        tile //= 2
    return max(tile, 1)


def _rowwise_call(kernel, out_count, rows, d, *arrays):
    """Launch a row-tiled kernel: (rows, d) arrays in, (rows, d) arrays out;
    rank-1 (d,) arrays broadcast to every tile."""
    tile = _row_tile(rows)
    in_specs = []
    for a in arrays:
        if a.ndim == 2:
            in_specs.append(pl.BlockSpec((tile, d), lambda i: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((d,), lambda i: (0,)))
    out_spec = pl.BlockSpec((tile, d), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((rows, d), arrays[0].dtype)
    if out_count > 1:
        out_spec = [out_spec] * out_count
        out_shape = [out_shape] * out_count
    return pl.pallas_call(
        kernel,
        grid=(rows // tile,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=True,
    )(*arrays)


# ------------------------------------------------------------- layernorm --

def _ln_fwd_kernel(x_ref, g_ref, b_ref, y_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y_ref[...] = (x - mu) / jnp.sqrt(var + EPS) * g_ref[...] + b_ref[...]


def _ln_xhat_kernel(x_ref, xhat_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    xhat_ref[...] = (x - mu) / jnp.sqrt(var + EPS)


def _ln_bwd_dx_kernel(x_ref, g_ref, dy_ref, dx_ref):
    """dx for y = xhat·g + b:
    dx = (dyg − mean(dyg) − xhat·mean(dyg·xhat)) / σ, with dyg = dy·g."""
    x = x_ref[...]
    dy = dy_ref[...]
    g = g_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    inv = 1.0 / jnp.sqrt(var + EPS)
    xhat = (x - mu) * inv
    dyg = dy * g
    m1 = jnp.mean(dyg, axis=-1, keepdims=True)
    m2 = jnp.mean(dyg * xhat, axis=-1, keepdims=True)
    dx_ref[...] = (dyg - m1 - xhat * m2) * inv


@jax.custom_vjp
def layernorm(x, g, b):
    """Row-wise layer normalization over the last axis of ``x[(rows, d)]``."""
    rows, d = x.shape
    return _rowwise_call(_ln_fwd_kernel, 1, rows, d, x, g, b)


def _ln_vjp_fwd(x, g, b):
    return layernorm(x, g, b), (x, g)


def _ln_vjp_bwd(res, dy):
    x, g = res
    rows, d = x.shape
    dx = _rowwise_call(_ln_bwd_dx_kernel, 1, rows, d, x, g, dy)
    xhat = _rowwise_call(_ln_xhat_kernel, 1, rows, d, x)
    dg = jnp.sum(dy * xhat, axis=0)
    db = jnp.sum(dy, axis=0)
    return dx, dg, db


layernorm.defvjp(_ln_vjp_fwd, _ln_vjp_bwd)


# ------------------------------------------------------------------ gelu --

_C = 0.7978845608028654  # sqrt(2/pi)
_A = 0.044715


def _gelu_fwd_kernel(x_ref, y_ref):
    x = x_ref[...]
    u = _C * (x + _A * x**3)
    y_ref[...] = 0.5 * x * (1.0 + jnp.tanh(u))


def _gelu_bwd_kernel(x_ref, dy_ref, dx_ref):
    x = x_ref[...]
    dy = dy_ref[...]
    u = _C * (x + _A * x**3)
    t = jnp.tanh(u)
    du = _C * (1.0 + 3.0 * _A * x**2)
    dx_ref[...] = dy * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t**2) * du)


@jax.custom_vjp
def gelu(x):
    """tanh-approximated GELU over ``x[(rows, d)]``, row-tiled."""
    rows, d = x.shape
    return _rowwise_call(_gelu_fwd_kernel, 1, rows, d, x)


def _gelu_vjp_fwd(x):
    return gelu(x), (x,)


def _gelu_vjp_bwd(res, dy):
    (x,) = res
    rows, d = x.shape
    return (_rowwise_call(_gelu_bwd_kernel, 1, rows, d, x, dy),)


gelu.defvjp(_gelu_vjp_fwd, _gelu_vjp_bwd)
