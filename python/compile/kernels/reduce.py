"""L1 Pallas kernel: blocked vector reduction (elementwise sum).

This is the collective data path's compute hot-spot — the "GPU vector
reduction kernel" of the paper's custom reduce-scatter (§III-B, Fig. 4) and
of PCCL's GPU-offloaded combines. On a real TPU the kernel streams both
operands through VMEM once (HBM-roofline bound, no MXU work by design);
here it is lowered with ``interpret=True`` so the CPU PJRT client can run
the resulting plain-HLO ops (see DESIGN.md §Hardware-Adaptation).

VMEM budget: BLOCK = 64 Ki f32 per operand ⇒ 3 buffers × 256 KiB = 768 KiB,
comfortably under the ~16 MiB VMEM of a TPU core while being large enough
to amortize grid overhead.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# f32 elements per VMEM block (256 KiB per operand buffer).
BLOCK = 64 * 1024


def _sum_kernel(x_ref, y_ref, o_ref):
    """One grid step: o = x + y over a VMEM-resident block."""
    o_ref[...] = x_ref[...] + y_ref[...]


def reduce_sum(x, y, block: int = BLOCK):
    """Elementwise ``x + y`` over equal-length rank-1 f32 buffers.

    The grid tiles the (flat) buffer in ``block``-element chunks; lengths
    must be a multiple of ``block`` (the Rust caller pads or falls back to
    its native reducer for the tail — measured faster than a pad-copy).
    """
    n = x.shape[0]
    if n % block != 0:
        raise ValueError(f"length {n} not a multiple of block {block}")
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _sum_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x, y)


def _sum_many_kernel(x_ref, o_ref):
    """K-way tree reduction of a (K, block) tile into (block,)."""
    o_ref[...] = jnp.sum(x_ref[...], axis=0)


def reduce_sum_many(stacked, block: int = BLOCK):
    """Reduce ``stacked[k, n]`` over axis 0 — the k-way combine used when a
    rank receives several partials in one hierarchical round."""
    k, n = stacked.shape
    if n % block != 0:
        raise ValueError(f"length {n} not a multiple of block {block}")
    grid = (n // block,)
    return pl.pallas_call(
        _sum_many_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), stacked.dtype),
        interpret=True,
    )(stacked)
