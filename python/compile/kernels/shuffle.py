"""L1 Pallas kernel: hierarchical unshuffle (device-local transpose).

Step 3 of the paper's two-level all-gather (Fig. 5): after the inter- then
intra-node gathers the output sits in ``(local_id, node)`` block order and
must be permuted to global ``(node, local_id)`` rank order. The paper
implements this "as a transpose kernel" on the GPU; here it is a Pallas
kernel whose grid walks the (node, local) block matrix and copies one
contiguous ``block``-sized chunk per step — every VMEM move is contiguous,
so the kernel is pure bandwidth (no lane shuffles needed).
"""

import jax
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def unshuffle(buf, n_nodes: int, m_local: int, block: int):
    """Permute ``(local, node, block)`` → ``(node, local, block)`` order.

    ``buf`` is the flat ``m_local·n_nodes·block`` buffer produced by the
    intra-node all-gather; the result is in global rank order.
    """
    n = buf.shape[0]
    if n != n_nodes * m_local * block:
        raise ValueError(f"buffer {n} != {m_local}x{n_nodes}x{block}")
    x = buf.reshape(m_local, n_nodes, block)
    out = pl.pallas_call(
        _copy_kernel,
        grid=(n_nodes, m_local),
        # Read block (l, n); write it to (n, l): the index maps express the
        # HBM↔VMEM schedule that a CUDA version would do with threadblocks.
        in_specs=[pl.BlockSpec((1, 1, block), lambda n_, l: (l, n_, 0))],
        out_specs=pl.BlockSpec((1, 1, block), lambda n_, l: (n_, l, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, m_local, block), buf.dtype),
        interpret=True,
    )(x)
    return out.reshape(-1)


def shuffle_gather(buf, n_nodes: int, m_local: int, block: int):
    """Inverse permutation (the reduce-scatter pre-shuffle)."""
    n = buf.shape[0]
    if n != n_nodes * m_local * block:
        raise ValueError(f"buffer {n} != {n_nodes}x{m_local}x{block}")
    x = buf.reshape(n_nodes, m_local, block)
    out = pl.pallas_call(
        _copy_kernel,
        grid=(m_local, n_nodes),
        in_specs=[pl.BlockSpec((1, 1, block), lambda l, n_: (n_, l, 0))],
        out_specs=pl.BlockSpec((1, 1, block), lambda l, n_: (l, n_, 0)),
        out_shape=jax.ShapeDtypeStruct((m_local, n_nodes, block), buf.dtype),
        interpret=True,
    )(x)
    return out.reshape(-1)
