"""Deterministic stand-in for `hypothesis` when it is not installed.

The CI image is offline, so property tests degrade to a fixed number of
seeded random examples per test. The API surface is the small subset the
kernel tests use: ``given``, ``settings``, ``strategies.integers``,
``strategies.sampled_from``. With real hypothesis installed the tests
import it instead and get full shrinking/replay behaviour.
"""

import random

_FALLBACK_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(options):
    opts = list(options)
    return _Strategy(lambda rng: rng.choice(opts))


class strategies:  # mirrors `from hypothesis import strategies as st`
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)


def settings(max_examples=_FALLBACK_EXAMPLES, deadline=None):
    del deadline  # no deadlines in the fallback

    def deco(f):
        f._max_examples = max_examples
        return f

    return deco


def given(**strats):
    def deco(f):
        # Deliberately zero-arg (no functools.wraps): pytest must not
        # mistake the drawn parameters for fixtures.
        def wrapper():
            rng = random.Random(0xC0FFEE)
            n = min(getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                f(**drawn)

        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper

    return deco
