"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
with hypothesis sweeping shapes and dtypes — the core build-time signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline image: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, strategies as st

from compile.kernels import fused, ref
from compile.kernels import reduce as kreduce
from compile.kernels import shuffle as kshuffle


def rand(shape, seed, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(dtype) * scale)


# ---------------------------------------------------------------- reduce --

@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=8),
    block=st.sampled_from([128, 1024, 4096]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_reduce_sum_matches_ref(blocks, block, seed):
    n = blocks * block
    x = rand((n,), seed)
    y = rand((n,), seed + 1)
    got = kreduce.reduce_sum(x, y, block=block)
    np.testing.assert_allclose(got, ref.reduce_sum(x, y), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=6),
    blocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_reduce_sum_many_matches_ref(k, blocks, seed):
    block = 512
    n = blocks * block
    stacked = rand((k, n), seed)
    got = kreduce.reduce_sum_many(stacked, block=block)
    np.testing.assert_allclose(got, ref.reduce_sum_many(stacked), rtol=1e-5, atol=1e-6)


def test_reduce_sum_rejects_unaligned():
    x = jnp.ones((100,), jnp.float32)
    with pytest.raises(ValueError):
        kreduce.reduce_sum(x, x, block=64)


def test_reduce_sum_f64():
    x = rand((2048,), 3, dtype=np.float64)
    y = rand((2048,), 4, dtype=np.float64)
    got = kreduce.reduce_sum(x, y, block=1024)
    np.testing.assert_allclose(got, x + y, rtol=1e-12)


# --------------------------------------------------------------- shuffle --

@settings(max_examples=25, deadline=None)
@given(
    n_nodes=st.integers(min_value=1, max_value=6),
    m_local=st.integers(min_value=1, max_value=6),
    block=st.sampled_from([1, 8, 64, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_unshuffle_matches_ref(n_nodes, m_local, block, seed):
    buf = rand((n_nodes * m_local * block,), seed)
    got = kshuffle.unshuffle(buf, n_nodes, m_local, block)
    np.testing.assert_array_equal(got, ref.unshuffle(buf, n_nodes, m_local, block))


@settings(max_examples=15, deadline=None)
@given(
    n_nodes=st.integers(min_value=1, max_value=5),
    m_local=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_shuffle_roundtrip(n_nodes, m_local, seed):
    block = 32
    buf = rand((n_nodes * m_local * block,), seed)
    once = kshuffle.unshuffle(buf, n_nodes, m_local, block)
    back = kshuffle.shuffle_gather(once, n_nodes, m_local, block)
    np.testing.assert_array_equal(back, buf)


def test_unshuffle_produces_rank_order():
    # Value = global rank of origin; M=2, N=2 (see Fig. 5).
    buf = jnp.asarray([0.0, 2.0, 1.0, 3.0])
    out = kshuffle.unshuffle(buf, 2, 2, 1)
    np.testing.assert_array_equal(out, [0.0, 1.0, 2.0, 3.0])


def test_shuffle_rejects_bad_shape():
    with pytest.raises(ValueError):
        kshuffle.unshuffle(jnp.ones((7,)), 2, 2, 2)


# ----------------------------------------------------------------- fused --

@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([1, 3, 32, 64, 96]),
    d=st.sampled_from([16, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_layernorm_matches_ref(rows, d, seed):
    x = rand((rows, d), seed, scale=3.0)
    g = rand((d,), seed + 1)
    b = rand((d,), seed + 2)
    got = fused.layernorm(x, g, b)
    np.testing.assert_allclose(got, ref.layernorm(x, g, b), rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([1, 8, 32, 80]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gelu_matches_ref(rows, seed):
    x = rand((rows, 64), seed, scale=2.0)
    got = fused.gelu(x)
    np.testing.assert_allclose(got, ref.gelu(x), rtol=1e-5, atol=1e-6)


def test_kernels_differentiable():
    # The model differentiates through the kernels; check grad flows.
    x = rand((8, 16), 0)
    g = jnp.ones((16,))
    b = jnp.zeros((16,))
    def f(x):
        return jnp.sum(fused.gelu(fused.layernorm(x, g, b)))
    grad = jax.grad(f)(x)
    assert grad.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(grad)))


# --------------------------------------------------- backward correctness --

@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([1, 4, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_layernorm_grad_matches_ref_autodiff(rows, seed):
    d = 32
    x = rand((rows, d), seed, scale=2.0)
    g = rand((d,), seed + 1)
    b = rand((d,), seed + 2)
    def f_kernel(x, g, b):
        return jnp.sum(jnp.sin(fused.layernorm(x, g, b)))
    def f_ref(x, g, b):
        return jnp.sum(jnp.sin(ref.layernorm(x, g, b)))
    got = jax.grad(f_kernel, argnums=(0, 1, 2))(x, g, b)
    want = jax.grad(f_ref, argnums=(0, 1, 2))(x, g, b)
    for gk, gr in zip(got, want):
        np.testing.assert_allclose(gk, gr, rtol=3e-4, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([1, 8, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gelu_grad_matches_ref_autodiff(rows, seed):
    x = rand((rows, 16), seed, scale=2.0)
    got = jax.grad(lambda x: jnp.sum(jnp.cos(fused.gelu(x))))(x)
    want = jax.grad(lambda x: jnp.sum(jnp.cos(ref.gelu(x))))(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
