"""L2 model checks: shapes, initialization determinism, loss sanity, and a
few SGD steps actually learning the synthetic successor task."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def tiny_cfg():
    return model.ModelConfig(
        vocab=64, seq=16, d_model=32, layers=2, heads=2, batch_per_rank=2
    )


def make_batch(cfg, seed=0):
    """Successor-rule tokens (mirrors rust train::data)."""
    rng = np.random.default_rng(seed)
    rows = []
    for _ in range(cfg.batch_per_rank):
        tok = int(rng.integers(cfg.vocab))
        row = [tok]
        for _ in range(cfg.seq):
            tok = (tok * 3 + 7) % cfg.vocab
            row.append(tok)
        rows.append(row)
    return jnp.asarray(rows, jnp.int32)


def test_param_spec_counts():
    cfg = tiny_cfg()
    spec = model.param_spec(cfg)
    assert len(spec) == 2 + 8 * cfg.layers + 3
    count = model.param_count(cfg)
    manual = sum(int(np.prod(s)) for _, s in spec)
    assert count == manual


def test_init_deterministic_and_shaped():
    cfg = tiny_cfg()
    p1 = model.init_params(jnp.int32(7), cfg)
    p2 = model.init_params(jnp.int32(7), cfg)
    p3 = model.init_params(jnp.int32(8), cfg)
    for a, b, (name, shape) in zip(p1, p2, model.param_spec(cfg)):
        assert a.shape == shape, name
        np.testing.assert_array_equal(a, b)
    assert any(
        not np.array_equal(a, c) for a, c in zip(p1, p3)
    ), "different seeds must differ"


def test_forward_shape_and_loss_near_uniform_at_init():
    cfg = tiny_cfg()
    params = model.init_params(jnp.int32(0), cfg)
    batch = make_batch(cfg)
    logits = model.forward(params, batch[:, :-1], cfg)
    assert logits.shape == (cfg.batch_per_rank, cfg.seq, cfg.vocab)
    loss = model.loss_fn(params, batch, cfg)
    # Fresh init ⇒ near-uniform predictions ⇒ loss ≈ ln(vocab).
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def test_train_step_returns_loss_and_grads():
    cfg = tiny_cfg()
    params = model.init_params(jnp.int32(0), cfg)
    out = model.train_step(params, make_batch(cfg), cfg)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


def test_few_sgd_steps_reduce_loss():
    cfg = tiny_cfg()
    params = model.init_params(jnp.int32(1), cfg)
    step = jax.jit(lambda ps, b: model.train_step(ps, b, cfg))
    first = None
    lr = 0.5
    loss = None
    for i in range(30):
        out = step(params, make_batch(cfg, seed=i))
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss)
        params = [p - lr * g for p, g in zip(params, grads)]
    assert float(loss) < first * 0.8, f"{first} → {float(loss)}"
