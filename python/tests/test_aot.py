"""AOT pipeline checks: HLO-text lowering round-trips through the format
the Rust loader expects, and the manifest contract is well-formed."""

import json
import os

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import reduce as kreduce


def test_to_hlo_text_is_parseable_hlo():
    f32 = jax.ShapeDtypeStruct((128,), jnp.float32)
    lowered = jax.jit(lambda x, y: (kreduce.reduce_sum(x, y, block=128),)).lower(f32, f32)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "f32[128]" in text
    # return_tuple=True: the root computation returns a tuple.
    assert "(f32[128]" in text


def test_lower_entry_writes_file_and_entry(tmp_path):
    f32 = jax.ShapeDtypeStruct((64,), jnp.float32)
    entry = aot.lower_entry(
        str(tmp_path),
        "toy",
        lambda x: (x * 2.0,),
        (f32,),
        [aot.spec((64,))],
        [aot.spec((64,))],
    )
    assert entry["file"] == "toy.hlo.txt"
    assert (tmp_path / "toy.hlo.txt").exists()
    assert entry["inputs"][0]["shape"] == [64]
    assert entry["outputs"][0]["dtype"] == "f32"


def test_manifest_contract_matches_model(tmp_path, monkeypatch):
    # Full pipeline into a temp dir with the (small) default config.
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out", str(tmp_path)]
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    names = set(manifest["entries"])
    assert {"init_params", "train_step"} <= names
    assert any(n.startswith("reduce_sum_") for n in names)
    assert any(n.startswith("unshuffle_") for n in names)
    # Every referenced file exists.
    for e in manifest["entries"].values():
        assert (tmp_path / e["file"]).exists()
    # Model metadata is internally consistent and matches model.py.
    cfg = model.ModelConfig()
    meta = manifest["model"]
    assert meta["vocab_size"] == cfg.vocab
    assert meta["seq_len"] == cfg.seq
    assert len(meta["param_names"]) == len(meta["param_shapes"])
    total = sum(
        int(jnp.prod(jnp.array(s))) for s in meta["param_shapes"]
    )
    assert total == meta["param_count"] == model.param_count(cfg)
    # train_step: inputs = params + tokens; outputs = loss + grads.
    ts = manifest["entries"]["train_step"]
    assert len(ts["inputs"]) == len(meta["param_names"]) + 1
    assert len(ts["outputs"]) == len(meta["param_names"]) + 1
    assert ts["outputs"][0]["shape"] == []
    assert ts["inputs"][-1]["dtype"] == "i32"
